"""Online memory-usage profiling (paper Sec. 4.1).

The profiler produces, at each decision interval, a snapshot of every shared
arena: its access count since profiling began (the paper never reweights by
default, Sec. 4.2) and its exact resident bytes per tier.  Access counts come
from the runtime's access model / device counters rather than PEBS samples —
see DESIGN.md Sec. 2 — but the downstream interface is identical to the
paper's: ``(site, cur_tier, accs, pages)`` tuples.

The profiler also times its own aggregation work so the framework can report
the per-interval profiling cost (the Table 2 measurement).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

from .arenas import Arena, ArenaManager
from .hwmodel import HardwareModel


@dataclasses.dataclass(frozen=True)
class ArenaProfile:
    """One row of an interval profile — mirrors Algorithm 1's tuple."""

    arena_id: int
    site_id: int
    label: str
    accesses: int
    resident_bytes: int
    fast_fraction: float

    @property
    def fast_bytes(self) -> int:
        return int(round(self.resident_bytes * self.fast_fraction))

    @property
    def slow_bytes(self) -> int:
        return self.resident_bytes - self.fast_bytes

    def density(self) -> float:
        """Accesses per byte — the sort key for hotset/thermos."""
        return self.accesses / self.resident_bytes if self.resident_bytes else 0.0


@dataclasses.dataclass
class IntervalProfile:
    """Snapshot of all shared arenas at one decision interval."""

    interval_index: int
    rows: List[ArenaProfile]
    private_pool_bytes: int
    collection_seconds: float

    def by_arena(self) -> Dict[int, ArenaProfile]:
        return {r.arena_id: r for r in self.rows}

    @property
    def total_bytes(self) -> int:
        return sum(r.resident_bytes for r in self.rows)

    @property
    def total_accesses(self) -> int:
        return sum(r.accesses for r in self.rows)


class OnlineProfiler:
    """Aggregates arena state into interval profiles.

    ``decay`` implements the optional ReweightProfile step of Algorithm 1:
    after every snapshot the accumulated access counters are multiplied by
    ``decay``.  The paper's evaluated configuration never reweights
    (``decay=1.0``), which is our default too.
    """

    def __init__(
        self,
        arenas: ArenaManager,
        hw: HardwareModel,
        decay: float = 1.0,
    ):
        if not (0.0 <= decay <= 1.0):
            raise ValueError("decay must be in [0, 1]")
        self.arenas = arenas
        self.hw = hw
        self.decay = decay
        self._interval = 0
        self.collection_times: List[float] = []

    def snapshot(self) -> IntervalProfile:
        t0 = time.perf_counter()
        rows = [
            ArenaProfile(
                arena_id=a.arena_id,
                site_id=a.site.site_id,
                label=a.site.label,
                accesses=a.accesses,
                resident_bytes=a.resident_bytes,
                fast_fraction=a.fast_fraction,
            )
            for a in self.arenas
        ]
        prof = IntervalProfile(
            interval_index=self._interval,
            rows=rows,
            private_pool_bytes=self.arenas.private_pool_bytes,
            collection_seconds=0.0,
        )
        if self.decay < 1.0:
            self.arenas.scale_access_counters(self.decay)
        elapsed = time.perf_counter() - t0
        prof = dataclasses.replace(prof, collection_seconds=elapsed)
        self.collection_times.append(elapsed)
        self._interval += 1
        return prof

    @property
    def mean_collection_seconds(self) -> float:
        return (
            sum(self.collection_times) / len(self.collection_times)
            if self.collection_times
            else 0.0
        )

    @property
    def max_collection_seconds(self) -> float:
        return max(self.collection_times) if self.collection_times else 0.0
