"""Hardware models for heterogeneous memory tiers.

The paper's ski-rental constants (EXTRA_NS_PER_SLOWER_ACCESS, NS_PER_PAGE_MOVED)
are properties of the platform.  We keep two calibrations:

* ``CLX``      — the paper's evaluation box (Cascade Lake, DDR4 + Optane DC).
                 Constants straight from the paper (Secs. 4.2, 5.1).
* ``TPU_V5E``  — the TPU target this framework adapts the technique to:
                 fast tier = on-chip HBM, slow tier = host DRAM over PCIe.

All byte-rate constants are in GB/s (1e9 bytes/s); latencies in ns.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One memory tier as seen by a single processor/chip."""

    name: str
    memory_kind: str          # jax memory kind used for enforcement
    capacity_bytes: int
    read_bw_GBps: float       # sustained read bandwidth
    write_bw_GBps: float      # sustained write bandwidth
    read_latency_ns: float    # average loaded read latency


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """Two-tier memory platform + the Algorithm-1 cost constants.

    ``extra_ns_per_slow_access`` is the paper's EXTRA_NS_PER_SLOWER_ACCESS: the
    average *additional* latency paid when an access that could have been served
    by the fast tier is served by the slow tier.

    ``ns_per_page_moved`` is NS_PER_PAGE_MOVED: the cost of remapping one
    ``page_bytes`` page between tiers.
    """

    name: str
    fast: TierSpec
    slow: TierSpec
    extra_ns_per_slow_access: float
    ns_per_page_moved: float
    page_bytes: int = 4096
    # Typical bytes touched per sampled "access"; the paper samples LLC-miss
    # loads (64 B lines).  The TPU model counts whole-arena touches, so its
    # access unit is 1 byte and access counts carry the byte volume.
    bytes_per_access: int = 64

    def pages(self, nbytes: int) -> int:
        return -(-int(nbytes) // self.page_bytes)

    def move_cost_ns(self, nbytes: int) -> float:
        return self.pages(nbytes) * self.ns_per_page_moved

    @property
    def slowdown_ratio(self) -> float:
        """Read-bandwidth ratio fast/slow (used by the simulator)."""
        return self.fast.read_bw_GBps / self.slow.read_bw_GBps


# ---------------------------------------------------------------------------
# The paper's platform: Intel Cascade Lake, 192 GB DDR4 + 768 GB Optane DC.
# DDR4: 6x32 GB 2933 MT/s  => ~100 GB/s sustained (paper Fig. 7 y-axis max).
# Optane: 30-40% of DDR4 read bw, +300 ns average extra read latency (Sec. 4.2),
# write bw 5-10x lower than DDR4 (Sec. 5.1).  move_pages ~= 2 us / 4 KB page.
# ---------------------------------------------------------------------------
CLX = HardwareModel(
    name="clx-ddr4-optane",
    fast=TierSpec(
        name="DRAM",
        memory_kind="device",
        capacity_bytes=192 * 2**30,
        read_bw_GBps=100.0,
        write_bw_GBps=80.0,
        read_latency_ns=90.0,
    ),
    slow=TierSpec(
        name="OPTANE",
        memory_kind="pinned_host",
        capacity_bytes=768 * 2**30,
        read_bw_GBps=35.0,          # 30-40% of DDR4
        write_bw_GBps=10.0,         # 5-10x lower than DDR4
        read_latency_ns=390.0,      # +300 ns over DDR4
    ),
    extra_ns_per_slow_access=300.0,  # Sec. 4.2
    ns_per_page_moved=2000.0,        # Sec. 4.2: ~2 us per 4 KB page
)


# ---------------------------------------------------------------------------
# The TPU adaptation target: one v5e chip.
#   fast tier  = HBM  (16 GB, 819 GB/s)
#   slow tier  = host DRAM reached over PCIe gen4 x8-ish (~16 GB/s effective
#                per chip on a 4-chip host; latency in the microseconds).
# The "access" unit for tier decisions is one byte of arena traffic, so
# extra_ns_per_slow_access is the per-byte bandwidth tax:
#   1/16 GB/s - 1/819 GB/s  =  0.0613 - 0.0012 ns/B  ~= 0.060 ns per byte.
# Page = 2 MiB arena block; moving it over PCIe at ~16 GB/s ~= 131 us, plus
# fixed descriptor overhead.
# ---------------------------------------------------------------------------
_TPU_PCIE_GBPS = 16.0
_TPU_HBM_GBPS = 819.0
_TPU_PAGE = 2 * 2**20

TPU_V5E = HardwareModel(
    name="tpu-v5e-hbm-host",
    fast=TierSpec(
        name="HBM",
        memory_kind="device",
        capacity_bytes=16 * 2**30,
        read_bw_GBps=_TPU_HBM_GBPS,
        write_bw_GBps=_TPU_HBM_GBPS,
        read_latency_ns=500.0,
    ),
    slow=TierSpec(
        name="HOST",
        memory_kind="pinned_host",
        capacity_bytes=512 * 2**30,
        read_bw_GBps=_TPU_PCIE_GBPS,
        write_bw_GBps=_TPU_PCIE_GBPS,
        read_latency_ns=2500.0,
    ),
    extra_ns_per_slow_access=(1.0 / _TPU_PCIE_GBPS - 1.0 / _TPU_HBM_GBPS),
    ns_per_page_moved=_TPU_PAGE / _TPU_PCIE_GBPS + 5000.0,
    page_bytes=_TPU_PAGE,
    bytes_per_access=1,
)


# Roofline constants for the target chip (used by benchmarks/roofline.py).
TPU_V5E_PEAK_BF16_FLOPS = 197e12     # per chip
TPU_V5E_HBM_GBPS = 819.0             # per chip
TPU_V5E_ICI_GBPS_PER_LINK = 50.0     # per link
