"""Capacity-profiling backends (paper Sec. 4.1.2 / Table 2).

Two real implementations of per-arena resident-set-size accounting whose
*collection* cost we measure:

* ``PagemapWalkRSS`` — the offline-style mechanism: residency is stored per
  4 KB page and collection *walks every page record* (the analogue of seek +
  read over /proc/pid/pagemap), locking each arena while it walks.

* ``VMACounterRSS`` — the paper's online mechanism: page-fault/release paths
  maintain a per-VMA counter, so collection reads one record per arena (the
  analogue of reading the custom proc interface).  A small format/parse
  round-trip per arena models the proc-file read.

Table 2's claim — >11x faster profile intervals — is validated by timing
``collect()`` on arenas shaped like the paper's benchmarks (same site counts
and resident GBs).
"""

from __future__ import annotations

import time
from typing import Dict, List

PAGE = 4096


class PagemapWalkRSS:
    """Offline-style: walk per-page residency records at collection time."""

    def __init__(self):
        self._pages: Dict[int, bytearray] = {}
        self.lock_events = 0

    def allocate(self, arena_id: int, nbytes: int) -> None:
        n_pages = -(-nbytes // PAGE)
        self._pages.setdefault(arena_id, bytearray()).extend(b"\x01" * n_pages)

    def release(self, arena_id: int, nbytes: int) -> None:
        pages = self._pages.get(arena_id)
        if pages is None:
            return
        n = -(-nbytes // PAGE)
        for i in range(len(pages) - 1, -1, -1):
            if n == 0:
                break
            if pages[i]:
                pages[i] = 0
                n -= 1

    def collect(self) -> Dict[int, int]:
        """Walk every page record (per-page Python work mimics the per-page
        syscall/parse cost of the pagemap approach)."""
        out: Dict[int, int] = {}
        for arena_id, pages in self._pages.items():
            self.lock_events += 1  # profiling thread must lock the arena
            count = 0
            for flag in pages:     # O(pages): the Sec. 4.1.2 drawback
                if flag:
                    count += 1
            out[arena_id] = count * PAGE
        return out


class VMACounterRSS:
    """Online: fault/release instrumentation keeps counters current; collect
    is one proc-interface read per arena."""

    def __init__(self):
        self._resident: Dict[int, int] = {}

    def allocate(self, arena_id: int, nbytes: int) -> None:
        n_pages = -(-nbytes // PAGE)
        self._resident[arena_id] = self._resident.get(arena_id, 0) + n_pages

    def release(self, arena_id: int, nbytes: int) -> None:
        n_pages = -(-nbytes // PAGE)
        cur = self._resident.get(arena_id, 0)
        self._resident[arena_id] = max(0, cur - n_pages)

    def collect(self) -> Dict[int, int]:
        out: Dict[int, int] = {}
        for arena_id, n_pages in self._resident.items():
            # Model the proc read: format + parse one line per VMA.
            line = f"{arena_id} {n_pages}\n"
            fields = line.split()
            out[int(fields[0])] = int(fields[1]) * PAGE
        return out


def time_collect(backend, repeats: int = 3) -> Dict[str, float]:
    times: List[float] = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        backend.collect()
        times.append(time.perf_counter() - t0)
    return {
        "mean_s": sum(times) / len(times),
        "max_s": max(times),
    }
