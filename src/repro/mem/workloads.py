"""Synthetic workloads shaped on the paper's Table 1.

Each constructor returns a ``SimWorkload`` whose aggregate statistics match
the corresponding benchmark: resident set size, allocation-site count, and a
memory-traffic profile calibrated so the *default / first-touch / guided*
throughput ratios land where the paper's Figures 5-8 put them.  The
calibration knobs are physical (traffic volume, read/write split, traffic
concentration across sites, latency-bound fraction) — the policies never see
them, only the resulting access counts.

Site-size and heat distributions are deterministic (seeded) lognormal/Zipf,
interleaved in allocation order so first-touch cannot accidentally capture
the hot set.
"""

from __future__ import annotations

from typing import List, Optional

import numpy as np

from .simulator import GB, SimSite, SimWorkload


def _sizes(total_bytes: int, n: int, rng: np.random.Generator,
           sigma: float = 1.6) -> np.ndarray:
    raw = rng.lognormal(mean=0.0, sigma=sigma, size=n)
    sizes = raw / raw.sum() * total_bytes
    return np.maximum(sizes.astype(np.int64), 4096)


def _heat(n: int, rng: np.random.Generator, zipf_s: float) -> np.ndarray:
    """Traffic share per site: Zipf over a random permutation of sites."""
    ranks = rng.permutation(n) + 1
    w = 1.0 / ranks.astype(np.float64) ** zipf_s
    return w / w.sum()


def build_hpc(
    name: str,
    total_gb: float,
    n_sites: int,
    read_GBps: float,
    write_GBps: float,
    zipf_s: float = 1.1,
    rand_frac: float = 0.08,
    phases: int = 60,
    seed: int = 7,
    compute_seconds: float = 1.0,
    dominant_site: Optional[dict] = None,
    size_heat_corr: float = 0.0,
    hot_alloc_late: float = 0.0,
) -> SimWorkload:
    """Generic memory-intensive HPC workload.

    ``dominant_site``: optional dict(frac_bytes, frac_traffic, hot_page_frac,
    hot_traffic_frac) — the QMCPACK pathology generator.
    ``size_heat_corr``: 0 = site size independent of heat; >0 biases heat
    toward *smaller* sites (stencil codes: small hot workset + big cold
    arrays), which is what makes guidance so profitable.
    """
    rng = np.random.default_rng(seed)
    total_bytes = int(total_gb * GB)
    sites: List[SimSite] = []

    dom_bytes = 0
    dom_traffic = 0.0
    if dominant_site is not None:
        dom_bytes = int(total_bytes * dominant_site["frac_bytes"])
        dom_traffic = dominant_site["frac_traffic"]
        n_rest = n_sites - 1
    else:
        n_rest = n_sites

    sizes = _sizes(total_bytes - dom_bytes, n_rest, rng)
    heat = _heat(n_rest, rng, zipf_s)
    if size_heat_corr > 0.0:
        # Re-rank: give the largest heat weights to the smallest sites with
        # probability proportional to corr.
        order_small = np.argsort(sizes)                # small first
        order_hot = np.argsort(-heat)
        mixed = np.empty(n_rest, dtype=np.int64)
        take_corr = rng.random(n_rest) < size_heat_corr
        pool_sorted = list(order_small)
        pool_rand = list(rng.permutation(n_rest))
        used = set()
        slots = []
        for i in range(n_rest):
            src = pool_sorted if take_corr[i] else pool_rand
            while src and src[0] in used:
                src.pop(0)
            if not src:
                src = pool_rand if take_corr[i] else pool_sorted
                while src and src[0] in used:
                    src.pop(0)
            pick = src.pop(0)
            used.add(pick)
            slots.append(pick)
        mixed[np.array(slots)] = order_hot[:n_rest]
        heat = heat[mixed]

    rest_traffic = 1.0 - dom_traffic
    for i in range(n_rest):
        share = heat[i] * rest_traffic
        sites.append(
            SimSite(
                name=f"{name}_site{i}",
                nbytes=int(sizes[i]),
                read_GBps=read_GBps * share,
                write_GBps=write_GBps * share,
                rand_frac=rand_frac,
                alloc_phase=0,
            )
        )
    if dominant_site is not None:
        sites.append(
            SimSite(
                name=f"{name}_dominant",
                nbytes=dom_bytes,
                read_GBps=read_GBps * dom_traffic,
                write_GBps=write_GBps * dom_traffic,
                rand_frac=rand_frac,
                hot_page_frac=dominant_site.get("hot_page_frac", 1.0),
                hot_traffic_frac=dominant_site.get("hot_traffic_frac", 1.0),
                fill_cold_first=dominant_site.get("fill_cold_first", True),
                alloc_phase=0,
            )
        )
    # Allocation order: ``hot_alloc_late`` biases hot sites toward late
    # allocation (HPC codes allocate big cold domain arrays at init and the
    # hot worksets later) — this is what starves first-touch.
    n = len(sites)
    traffic = np.array([s.read_GBps + s.write_GBps for s in sites])
    dens = traffic / np.maximum(np.array([s.nbytes for s in sites]), 1)
    dens_rank = np.argsort(np.argsort(dens)) / max(n - 1, 1)  # 1.0 = hottest
    key = rng.random(n) * (1.0 - hot_alloc_late) + dens_rank * hot_alloc_late
    order = np.argsort(key)  # cold first, hot last (to the chosen degree)
    sites = [sites[i] for i in order]
    return SimWorkload(name=name, sites=sites, phases=phases,
                       compute_seconds=compute_seconds)


# ---------------------------------------------------------------- CORAL set
# Traffic calibration targets (paper Fig. 6, medium inputs):
#   LULESH: guided up to ~7.3x over first-touch at 20% DRAM.
#   AMG/SNAP: 1.4x-4x range.  QMCPACK: up to ~7.1x at 50%.
# Write-heavy hot sites are what make first-touch so bad on Optane
# (5-10x lower write bandwidth, Sec. 5.1).

def lulesh(input_size: str = "medium") -> SimWorkload:
    gb = {"medium": 66.2, "large": 522.9, "huge": 627.3}[input_size]
    return build_hpc(
        f"lulesh_{input_size}", gb, n_sites=87,
        read_GBps=180.0, write_GBps=120.0,
        zipf_s=1.2, rand_frac=0.12, size_heat_corr=0.2, hot_alloc_late=0.3,
        phases=60, seed=11,
    )


def amg(input_size: str = "medium") -> SimWorkload:
    gb = {"medium": 72.2, "large": 260.4, "huge": 392.4}[input_size]
    return build_hpc(
        f"amg_{input_size}", gb, n_sites=209,
        read_GBps=150.0, write_GBps=40.0,
        zipf_s=0.8, rand_frac=0.15, size_heat_corr=0.1, hot_alloc_late=0.1,
        phases=60, seed=13,
    )


def snap(input_size: str = "medium") -> SimWorkload:
    gb = {"medium": 61.4, "large": 288.8, "huge": 462.1}[input_size]
    return build_hpc(
        f"snap_{input_size}", gb, n_sites=90,
        read_GBps=130.0, write_GBps=45.0,
        zipf_s=0.7, rand_frac=0.05, size_heat_corr=0.0, hot_alloc_late=0.15,
        phases=60, seed=17,
    )


def qmcpack(input_size: str = "medium") -> SimWorkload:
    gb = {"medium": 16.5, "large": 357.0, "huge": 375.9}[input_size]
    # Large/huge inputs: one site allocates 60-63% of resident data and is
    # the hottest per byte on average, but only ~1/3 of its pages are hot at
    # any time (Sec. 6.3) — the site-granularity pathology.
    dom = None
    read, write, rand = 60.0, 15.0, 0.10
    if input_size in ("large", "huge"):
        dom = dict(frac_bytes=0.62, frac_traffic=0.85,
                   hot_page_frac=0.25, hot_traffic_frac=0.97)
        read, write, rand = 130.0, 25.0, 0.15
    return build_hpc(
        f"qmcpack_{input_size}", gb, n_sites=1408,
        read_GBps=read, write_GBps=write,
        zipf_s=1.0, rand_frac=rand, size_heat_corr=0.1, hot_alloc_late=0.3,
        phases=60, seed=19, dominant_site=dom,
    )


CORAL = {"lulesh": lulesh, "amg": amg, "snap": snap, "qmcpack": qmcpack}


# ----------------------------------------------------------------- SPEC set
# SPEC CPU 2017 FP (OpenMP subset).  Far smaller footprints; several are
# compute-bound and get little or no benefit from guidance (Fig. 6 bottom).

def spec_workload(name: str, gb: float, n_sites: int, read_GBps: float,
                  write_GBps: float, zipf_s: float, rand_frac: float,
                  memory_bound: float, seed: int,
                  hot_alloc_late: float = 0.0) -> SimWorkload:
    """``memory_bound``: ratio of nominal memory stall to compute at default
    placement — <1 means guidance has little to win."""
    wl = build_hpc(
        name, gb, n_sites=n_sites,
        read_GBps=read_GBps * memory_bound,
        write_GBps=write_GBps * memory_bound,
        zipf_s=zipf_s, rand_frac=rand_frac, size_heat_corr=0.1,
        hot_alloc_late=hot_alloc_late,
        phases=40, seed=seed,
    )
    return wl


SPEC = {
    # (Fig. 6 bottom) bwaves/pop2/fotonik3d/roms benefit modestly;
    # cactuBSSN, wrf, imagick, nab are compute-bound and see little or none
    # (the online runs there pay the profiling thread for nothing).
    "bwaves": lambda: spec_workload("bwaves", 11.4, 34, 110, 25, 0.7, 0.01, 0.33, 23, 0.1),
    "cactuBSSN": lambda: spec_workload("cactuBSSN", 6.6, 809, 40, 10, 0.7, 0.01, 0.2, 29),
    "wrf": lambda: spec_workload("wrf", 0.2, 4869, 30, 8, 0.7, 0.01, 0.15, 31),
    "cam4": lambda: spec_workload("cam4", 1.2, 1691, 35, 10, 0.8, 0.01, 0.25, 37),
    "pop2": lambda: spec_workload("pop2", 1.5, 1107, 120, 24, 0.9, 0.01, 0.32, 41, 0.25),
    "imagick": lambda: spec_workload("imagick", 6.9, 4, 25, 8, 0.5, 0.01, 0.12, 43),
    "nab": lambda: spec_workload("nab", 0.6, 88, 25, 6, 0.6, 0.01, 0.12, 47),
    "fotonik3d": lambda: spec_workload("fotonik3d", 9.5, 127, 100, 20, 0.7, 0.01, 0.35, 53, 0.1),
    "roms": lambda: spec_workload("roms", 10.2, 395, 115, 28, 0.8, 0.01, 0.36, 59, 0.15),
}
