"""repro.mem — calibrated two-tier memory simulator + workload models used
for the paper-faithful reproduction experiments (see DESIGN.md Sec. 9)."""

from .simulator import (
    GB,
    MemorySimulator,
    PhaseRecord,
    SimArenaBackend,
    SimResult,
    SimSite,
    SimWorkload,
)
from . import workloads

__all__ = [
    "GB",
    "MemorySimulator",
    "PhaseRecord",
    "SimArenaBackend",
    "SimResult",
    "SimSite",
    "SimWorkload",
    "workloads",
]
