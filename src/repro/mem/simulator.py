"""Trace-driven two-tier memory simulator — the faithful-reproduction rig.

This container has neither Optane DIMMs nor a TPU, so the paper's evaluation
platform (Sec. 5.1) is reproduced as a calibrated discrete-time model.  The
*policies* under test are the real framework code: the online policy runs the
actual ``repro.core`` stack (hybrid arenas -> online profiler -> thermos ->
ski-rental -> enforcement); the simulator only supplies the timing model that
real hardware would.

Timing model (per phase of nominal ``phase_seconds`` compute):

  wall = max(compute, mem_stall) + migration_stall + profile_overhead

  mem_stall  = sum over sites of   read_f/BWr_f + read_s/BWr_s
                                 + write_f/BWw_f + write_s/BWw_s
                                 + slow_rand_reads * extra_latency / MLP

where the fast/slow traffic split follows the site's current placement at
*page-group* granularity: each site divides into a hot page group
(``hot_page_frac`` of bytes receiving ``hot_traffic_frac`` of traffic) and a
cold group.  Site-granularity policies place bytes without seeing the groups
(fast fraction f covers the hot group first only by luck of fraction size —
we model placement as byte-uniform: traffic served fast = f-weighted mix);
page-granularity mechanisms (hardware caching, fragmentation) exploit them.

The numbers in ``hwmodel.CLX`` come straight from the paper: +300 ns Optane
read latency, 2 us per 4 KB page moved, 30-40 % read bandwidth, 5-10x lower
write bandwidth.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from ..core import (
    ArenaBackend,
    ArenaManager,
    ChunkStats,
    FractionPlacer,
    GuidanceConfig,
    GuidanceRuntime,
    HardwareModel,
    SiteKind,
    SiteRegistry,
    static_plan,
)
from ..core.profiler import ArenaProfile, IntervalProfile

GB = float(2**30)
LINE = 64  # bytes per sampled access (LLC line)
MLP = 6.0  # memory-level parallelism hiding part of the latency tax


# --------------------------------------------------------------------- sites
@dataclasses.dataclass
class SimSite:
    """One allocation site of a simulated workload."""

    name: str
    nbytes: int
    read_GBps: float            # read traffic at full speed
    write_GBps: float = 0.0     # write traffic at full speed
    rand_frac: float = 0.3      # fraction of reads that are latency-bound
    hot_page_frac: float = 1.0  # fraction of bytes that are "hot pages"
    hot_traffic_frac: float = 1.0  # fraction of traffic hitting hot pages
    alloc_phase: int = 0        # phase at which the site is allocated
    phase_mult: Optional[Sequence[float]] = None  # per-phase intensity scale
    # The QMCPACK pathology (Sec. 6.3): the hot pages are the *youngest*
    # (fresh walker data), but site-granularity placement fills the fast
    # tier with the site's oldest bytes first.  Age-aware mechanisms
    # (hardware caching, our fragmentation) still find the hot pages.
    fill_cold_first: bool = False

    def intensity(self, phase: int) -> float:
        if self.phase_mult is None:
            return 1.0
        return self.phase_mult[min(phase, len(self.phase_mult) - 1)]


@dataclasses.dataclass
class SimWorkload:
    name: str
    sites: List[SimSite]
    phases: int                  # number of nominal-1s compute phases
    compute_seconds: float = 1.0  # pure compute per phase at 16 threads

    @property
    def peak_rss(self) -> int:
        return sum(s.nbytes for s in self.sites)


# -------------------------------------------------------------------- result
@dataclasses.dataclass
class PhaseRecord:
    phase: int
    wall_seconds: float
    mem_seconds: float
    bytes_fast: int
    bytes_migrated: int
    bandwidth_GBps: float


@dataclasses.dataclass
class SimResult:
    workload: str
    policy: str
    cap_bytes: int
    total_seconds: float
    phase_records: List[PhaseRecord]
    bytes_migrated: int
    profile_seconds: float

    @property
    def throughput(self) -> float:
        return len(self.phase_records) / self.total_seconds

    def speedup_over(self, other: "SimResult") -> float:
        return self.throughput / other.throughput


# ------------------------------------------------------------------ backend
class SimArenaBackend(ArenaBackend):
    """``TierBackend`` for the simulator: an ``ArenaBackend`` whose chunk
    telemetry comes from the workload model's hot/cold page groups.

    Beyond-paper (Sec. 6.3 fix): when ``fragmentation`` is on, every site
    with intra-site heterogeneity reports two chunks — the young hot page
    group and the old cold group — so the *core* loop explodes the arena
    into age fragments and places the groups independently.  The simulator
    itself no longer carries any Algorithm-1 logic.
    """

    name = "sim_arena"

    def __init__(self, arenas, hw, placer, workload: SimWorkload,
                 arena_of: Dict[str, object], fragmentation: bool = False):
        super().__init__(arenas, hw, placer=placer)
        self.wl = workload
        self.arena_of = arena_of          # site name -> Arena (caller-owned)
        self.fragmentation = fragmentation
        self._profile: Optional[IntervalProfile] = None

    def snapshot(self) -> IntervalProfile:
        self._profile = super().snapshot()
        return self._profile

    def telemetry(self):
        if not self.fragmentation or self._profile is None:
            return {}
        telemetry: Dict[int, List[ChunkStats]] = {}
        by_arena = self._profile.by_arena()
        for s in self.wl.sites:
            arena = self.arena_of.get(s.name)
            if arena is None or s.hot_page_frac >= 1.0:
                continue
            row = by_arena.get(arena.arena_id)
            if row is None:
                continue
            hot_b = int(s.nbytes * s.hot_page_frac)
            telemetry[arena.arena_id] = [
                ChunkStats(chunk_id=arena.arena_id * 2, nbytes=hot_b,
                           accesses=int(row.accesses * s.hot_traffic_frac),
                           age=0, fast=row.fast_fraction > 0.5),
                ChunkStats(chunk_id=arena.arena_id * 2 + 1,
                           nbytes=s.nbytes - hot_b,
                           accesses=int(row.accesses * (1 - s.hot_traffic_frac)),
                           age=1, fast=False),
            ]
        return telemetry


# ----------------------------------------------------------------- simulator
class MemorySimulator:
    """Executes a workload under a placement policy and the CLX timing model."""

    def __init__(self, hw: HardwareModel, workload: SimWorkload):
        self.hw = hw
        self.wl = workload

    # -- timing -------------------------------------------------------------
    def _site_stall(self, site: SimSite, fast_frac_hot: float,
                    fast_frac_cold: float, phase: int) -> float:
        """Memory stall seconds for one site in one phase, given the fast-tier
        coverage of its hot and cold page groups."""
        hw = self.hw
        scale = site.intensity(phase)
        reads = site.read_GBps * GB * scale * self.wl.compute_seconds
        writes = site.write_GBps * GB * scale * self.wl.compute_seconds
        h, p = site.hot_page_frac, site.hot_traffic_frac
        # Split traffic into (hot, cold) page groups.
        r_hot, r_cold = reads * p, reads * (1 - p)
        w_hot, w_cold = writes * p, writes * (1 - p)
        rf = r_hot * fast_frac_hot + r_cold * fast_frac_cold
        rs = (r_hot + r_cold) - rf
        wf = w_hot * fast_frac_hot + w_cold * fast_frac_cold
        ws = (w_hot + w_cold) - wf
        t = (
            rf / (hw.fast.read_bw_GBps * GB)
            + rs / (hw.slow.read_bw_GBps * GB)
            + wf / (hw.fast.write_bw_GBps * GB)
            + ws / (hw.slow.write_bw_GBps * GB)
        )
        # Latency tax on random slow reads.
        slow_rand_lines = rs * site.rand_frac / LINE
        t += slow_rand_lines * (hw.extra_ns_per_slow_access / MLP) * 1e-9
        return t

    @staticmethod
    def _group_coverage(site: SimSite, fast_fraction: float,
                        page_aware: bool) -> tuple:
        """How much of the site's hot/cold page groups the fast bytes cover.

        Site-granularity placement is byte-uniform (the allocator cannot tell
        hot pages from cold within an arena): both groups get ``fast_fraction``
        coverage.  Page-aware mechanisms (hw cache, fragmentation) fill the hot
        group first.
        """
        h = site.hot_page_frac
        if page_aware:
            # Hot pages claimed first (hw cache / age-fragmented guidance).
            hot_cov = min(1.0, fast_fraction / h) if h > 0 else 1.0
            spare = max(0.0, fast_fraction - h)
            cold_cov = spare / (1.0 - h) if h < 1.0 else 1.0
            return hot_cov, min(1.0, cold_cov)
        if site.fill_cold_first:
            # Oldest (cold) bytes land fast first; the young hot set spills.
            cold_cov = min(1.0, fast_fraction / (1.0 - h)) if h < 1.0 else 1.0
            spare = max(0.0, fast_fraction - (1.0 - h))
            hot_cov = spare / h if h > 0 else 1.0
            return min(1.0, hot_cov), cold_cov
        return fast_fraction, fast_fraction

    # -- policy drivers -------------------------------------------------------
    def run_all_fast(self) -> SimResult:
        """The paper's *default* configuration: everything in DRAM, 16 threads."""
        return self._run_static(
            "default", cap=self.wl.peak_rss, fractions=None, compute_scale=1.0
        )

    def run_first_touch(self, cap: int) -> SimResult:
        """Unguided baseline: allocation-order fill of the fast tier."""
        fractions: Dict[str, float] = {}
        free = cap
        for s in sorted(self.wl.sites, key=lambda s: (s.alloc_phase,)):
            take = min(s.nbytes, max(free, 0))
            fractions[s.name] = take / s.nbytes if s.nbytes else 1.0
            free -= take
        return self._run_static("first_touch", cap, fractions, compute_scale=1.0)

    def run_offline(self, cap: int, strategy: str = "thermos") -> SimResult:
        """Offline MemBrain: oracle whole-run profile -> static placement."""
        prof = self._oracle_profile()
        recs = static_plan(prof, cap, strategy)
        id2name = {i: s.name for i, s in enumerate(self.wl.sites)}
        fractions = {
            id2name[aid]: frac for aid, frac in recs.fractions.items()
        }
        return self._run_static(f"offline_{strategy}", cap, fractions, 1.0)

    def _oracle_profile(self) -> IntervalProfile:
        rows = []
        for i, s in enumerate(self.wl.sites):
            total_phases = self.wl.phases - s.alloc_phase
            scale = sum(s.intensity(p) for p in range(s.alloc_phase, self.wl.phases))
            traffic = (s.read_GBps + s.write_GBps) * GB * self.wl.compute_seconds * scale
            rows.append(
                ArenaProfile(
                    arena_id=i, site_id=i, label=s.name,
                    accesses=int(traffic / LINE),
                    resident_bytes=s.nbytes, fast_fraction=1.0,
                )
            )
        return IntervalProfile(0, rows, 0, 0.0)

    def _run_static(self, policy: str, cap: int,
                    fractions: Optional[Dict[str, float]],
                    compute_scale: float) -> SimResult:
        records = []
        total = 0.0
        for phase in range(self.wl.phases):
            mem = 0.0
            fast_bytes = 0
            for s in self.wl.sites:
                if phase < s.alloc_phase:
                    continue
                f = 1.0 if fractions is None else fractions.get(s.name, 0.0)
                hot_cov, cold_cov = self._group_coverage(s, f, page_aware=False)
                mem += self._site_stall(s, hot_cov, cold_cov, phase)
                fast_bytes += int(f * s.nbytes)
            compute = self.wl.compute_seconds * compute_scale
            wall = max(compute, mem)
            traffic = self._phase_traffic(phase)
            records.append(PhaseRecord(phase, wall, mem, fast_bytes, 0,
                                       traffic / wall / GB if wall else 0.0))
            total += wall
        return SimResult(self.wl.name, policy, cap, total, records, 0, 0.0)

    def _phase_traffic(self, phase: int) -> float:
        return sum(
            (s.read_GBps + s.write_GBps) * GB * s.intensity(phase)
            * self.wl.compute_seconds
            for s in self.wl.sites
            if phase >= s.alloc_phase
        )

    # -- the real thing: online GDT ------------------------------------------
    def run_online(
        self,
        cap: int,
        strategy: str = "thermos",
        interval_seconds: float = 10.0,
        fragmentation: bool = False,
        num_fragments: int = 4,
        profile_cost_per_interval: float = 0.05,
        compute_scale: float = 16.0 / 15.0,
    ) -> SimResult:
        """Online guided data tiering: first-touch start, then Algorithm 1
        at wall-clock intervals, driven by the shared ``GuidanceRuntime``
        over a ``SimArenaBackend`` (the same controller that drives the
        trainer and the serving engine)."""
        reg = SiteRegistry()
        mgr = ArenaManager(reg, fast_capacity_bytes=cap)
        # Register sites; allocation happens at alloc_phase.
        core_sites = {s.name: reg.register([s.name], SiteKind.OTHER) for s in self.wl.sites}
        arena_of: Dict[str, object] = {}
        backend = SimArenaBackend(mgr, self.hw, FractionPlacer(mgr),
                                  self.wl, arena_of,
                                  fragmentation=fragmentation)
        runtime = GuidanceRuntime(
            backend, self.hw,
            GuidanceConfig(strategy=strategy, fast_capacity_bytes=cap,
                           interval_steps=1,
                           num_fragments=max(2, num_fragments)))

        records: List[PhaseRecord] = []
        total = 0.0
        total_migrated = 0
        profile_time = 0.0
        next_decision = interval_seconds
        for phase in range(self.wl.phases):
            # Allocations due this phase (first-touch placement inside mgr).
            for s in self.wl.sites:
                if s.alloc_phase == phase:
                    arena_of[s.name] = mgr.allocate(core_sites[s.name], s.nbytes)
            # Account accesses + compute stall under *current* placement.
            mem = 0.0
            migrated = 0
            for s in self.wl.sites:
                if phase < s.alloc_phase:
                    continue
                arena = arena_of[s.name]
                f = arena.fast_fraction if arena is not None else 1.0
                hot_cov, cold_cov = self._group_coverage(
                    s, f, page_aware=fragmentation
                )
                mem += self._site_stall(s, hot_cov, cold_cov, phase)
                traffic = (
                    (s.read_GBps + s.write_GBps) * GB
                    * s.intensity(phase) * self.wl.compute_seconds
                )
                mgr.touch(core_sites[s.name], int(traffic / LINE))
            compute = self.wl.compute_seconds * compute_scale
            wall = max(compute, mem)
            # Decision interval(s) that elapse during this phase.
            if total + wall >= next_decision:
                next_decision += interval_seconds
                rec = runtime.on_step()
                profile_time += profile_cost_per_interval
                wall += profile_cost_per_interval
                if rec is not None and rec.migrated:
                    migrated = rec.bytes_moved
                    total_migrated += migrated
                    wall += self.hw.move_cost_ns(migrated) * 1e-9
            traffic = self._phase_traffic(phase)
            records.append(PhaseRecord(phase, wall, mem,
                                       mgr.fast_tier_bytes(), migrated,
                                       traffic / wall / GB if wall else 0.0))
            total += wall
        return SimResult(self.wl.name, f"online_{strategy}", cap, total,
                         records, total_migrated, profile_time)

    # -- hardware-managed DRAM cache ("memory mode") ---------------------------
    def run_hw_cache(self, cap: int) -> SimResult:
        """Intel memory mode: DRAM is a direct-mapped page-granularity cache
        of Optane.  Page-aware (hot groups cached first, globally by density)
        but pays cache-management traffic on misses/evictions."""
        # Global page-group list: (density, site, group) with group hot/cold.
        groups = []
        for s in self.wl.sites:
            hot_b = int(s.nbytes * s.hot_page_frac)
            cold_b = s.nbytes - hot_b
            traffic = (s.read_GBps + s.write_GBps) * GB * self.wl.compute_seconds
            if hot_b:
                groups.append((traffic * s.hot_traffic_frac / hot_b, s, "hot", hot_b))
            if cold_b:
                groups.append((traffic * (1 - s.hot_traffic_frac) / cold_b, s,
                               "cold", cold_b))
        groups.sort(key=lambda g: -g[0])
        cached: Dict[tuple, float] = {}
        free = cap
        for dens, s, kind, nb in groups:
            take = min(nb, max(free, 0))
            cached[(s.name, kind)] = take / nb if nb else 1.0
            free -= take
        # Direct-mapped conflicts: real caches do not achieve perfect
        # hot-first packing; degrade coverage by a conflict factor.
        conflict = 0.85
        records = []
        total = 0.0
        mgmt_traffic_total = 0.0
        for phase in range(self.wl.phases):
            mem = 0.0
            for s in self.wl.sites:
                if phase < s.alloc_phase:
                    continue
                hot_cov = cached.get((s.name, "hot"), 0.0) * conflict
                cold_cov = cached.get((s.name, "cold"), 0.0) * conflict
                mem += self._site_stall(s, hot_cov, cold_cov, phase)
                # Cache management: misses pull lines from Optane AND write
                # them to DRAM; dirty evictions write back.  Model as extra
                # slow-tier traffic proportional to miss traffic.
                traffic = ((s.read_GBps + s.write_GBps) * GB
                           * s.intensity(phase) * self.wl.compute_seconds)
                h, p = s.hot_page_frac, s.hot_traffic_frac
                miss = traffic * (p * (1 - hot_cov) + (1 - p) * (1 - cold_cov))
                mgmt = 0.5 * miss   # fill + eviction overhead
                mem += mgmt / (self.hw.slow.read_bw_GBps * GB)
                mgmt_traffic_total += mgmt
            compute = self.wl.compute_seconds
            wall = max(compute, mem)
            records.append(PhaseRecord(phase, wall, mem, cap, 0,
                                       self._phase_traffic(phase) / wall / GB))
            total += wall
        return SimResult(self.wl.name, "hw_cache", cap, total, records,
                         int(mgmt_traffic_total), 0.0)
