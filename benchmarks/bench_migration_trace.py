"""Fig. 7 reproduction: per-interval total memory bandwidth and GBs migrated
over time for the CORAL benchmarks (medium input, 50% DRAM cap, online
policy).  ``derived`` for the summary rows = fraction of all migrated bytes
that moved in the first quarter of the run (the Fig. 7 'startup' shape);
per-phase rows report bandwidth in GB/s."""

from __future__ import annotations

from repro.core import CLX
from repro.mem import GB, MemorySimulator
from repro.mem.workloads import CORAL

from .common import emit


def run(quick: bool = False, trace: bool = False):
    rows = []
    for name, wlf in CORAL.items():
        wl = wlf("medium")
        sim = MemorySimulator(CLX, wl)
        res = sim.run_online(int(wl.peak_rss * 0.5))
        total_mig = sum(p.bytes_migrated for p in res.phase_records) or 1
        n = len(res.phase_records)
        first_q = sum(p.bytes_migrated for p in res.phase_records[: n // 4])
        rows.append(
            (
                f"fig7/{wl.name}/early_migration_frac",
                res.total_seconds * 1e6,
                first_q / total_mig,
            )
        )
        rows.append(
            (
                f"fig7/{wl.name}/total_migrated_GB",
                res.total_seconds * 1e6,
                res.bytes_migrated / GB,
            )
        )
        if trace:
            for p in res.phase_records:
                rows.append(
                    (
                        f"fig7/{wl.name}/phase{p.phase:03d}/bw",
                        p.wall_seconds * 1e6,
                        p.bandwidth_GBps,
                    )
                )
    return emit(rows)


if __name__ == "__main__":
    run(trace=True)
