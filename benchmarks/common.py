"""Shared benchmark plumbing: every bench module exposes ``run() -> rows``
where each row is ``(name, us_per_call, derived)``; ``derived`` is the
figure-of-merit the corresponding paper table/figure reports (usually a
speedup ratio).  ``benchmarks.run`` aggregates all modules into one CSV."""

from __future__ import annotations

import time
from typing import Callable, Iterable, List, Tuple

Row = Tuple[str, float, float]


def timed(fn: Callable, *args, **kwargs):
    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    return out, (time.perf_counter() - t0) * 1e6


def emit(rows: Iterable[Row]) -> List[Row]:
    rows = list(rows)
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived:.4f}")
    return rows
