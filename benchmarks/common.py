"""Shared benchmark plumbing: every bench module exposes ``run() -> rows``
where each row is ``(name, us_per_call, derived)``; ``derived`` is the
figure-of-merit the corresponding paper table/figure reports (usually a
speedup ratio).  ``benchmarks.run`` aggregates all modules into one CSV and
(on ``--smoke``) persists each group's rows as ``BENCH_<group>.json`` so the
perf trend is tracked across PRs instead of evaporating with the CI log."""

from __future__ import annotations

import datetime
import json
import os
import subprocess
import time
from typing import Callable, Iterable, List, Optional, Tuple

Row = Tuple[str, float, float]


def timed(fn: Callable, *args, **kwargs):
    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    return out, (time.perf_counter() - t0) * 1e6


def emit(rows: Iterable[Row]) -> List[Row]:
    rows = list(rows)
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived:.4f}")
    return rows


def git_rev() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, cwd=os.path.dirname(os.path.dirname(
                os.path.abspath(__file__)))).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError, ValueError):
        return "unknown"


def write_bench_json(group: str, rows: Iterable[Row],
                     out_dir: Optional[str] = None) -> str:
    """Persist one benchmark group's trajectory as ``BENCH_<group>.json``:
    the rows plus the git revision and a UTC timestamp, so a checked-out
    artifact pins exactly which tree produced which numbers."""
    path = os.path.join(out_dir or os.getcwd(), f"BENCH_{group}.json")
    payload = {
        "group": group,
        "git_rev": git_rev(),
        "timestamp_utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(),
        "rows": [{"name": name, "us_per_call": us, "derived": derived}
                 for name, us, derived in rows],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    return path
