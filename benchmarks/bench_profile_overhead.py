"""Fig. 5 + Table 2 reproduction.

Part A (Table 2): per-interval profile collection time for the offline-style
pagemap walk vs the online VMA-counter mechanism, on arenas shaped like each
paper benchmark (site count x resident GB from Table 1).  ``derived`` =
seconds per collection; the summary rows report the offline/online ratio
(paper: >11x mean reduction).

Part B (Fig. 5): execution-time overhead of online profiling in the *real*
JAX runtime — a small training loop run with profiling off vs on.
``derived`` = relative execution time (1.0 = no overhead).
"""

from __future__ import annotations

import time

from repro.core import ArenaManager, CLX, OnlineProfiler, SiteKind, SiteRegistry
from repro.mem import GB
from repro.mem.rss_backends import PagemapWalkRSS, VMACounterRSS, time_collect

from .common import emit

# (name, resident GB, reached allocation sites) from Table 1.
TABLE1 = [
    ("lulesh", 66.2, 87),
    ("amg", 72.2, 209),
    ("snap", 61.4, 87),
    ("qmcpack", 16.5, 1408),
    ("bwaves", 11.4, 34),
    ("cactuBSSN", 6.6, 809),
    ("wrf", 0.2, 4869),
    ("cam4", 1.2, 1691),
    ("pop2", 1.5, 1107),
    ("imagick", 6.9, 4),
    ("nab", 0.6, 88),
    ("fotonik3d", 9.5, 127),
    ("roms", 10.2, 395),
]


def _populate(backend, gb: float, sites: int) -> None:
    per_site = int(gb * GB / sites)
    for i in range(sites):
        backend.allocate(i, per_site)


def table2(quick: bool = False):
    rows = []
    ratios = []
    cases = TABLE1 if not quick else TABLE1[:4]
    for name, gb, sites in cases:
        walk = PagemapWalkRSS()
        vma = VMACounterRSS()
        _populate(walk, gb, sites)
        _populate(vma, gb, sites)
        t_walk = time_collect(walk, repeats=2 if not quick else 1)
        t_vma = time_collect(vma, repeats=5)
        rows.append((f"table2/{name}/offline_walk", t_walk["mean_s"] * 1e6,
                     t_walk["mean_s"]))
        rows.append((f"table2/{name}/online_vma", t_vma["mean_s"] * 1e6,
                     t_vma["mean_s"]))
        ratios.append(t_walk["mean_s"] / max(t_vma["mean_s"], 1e-9))
    mean_ratio = sum(ratios) / len(ratios)
    rows.append(("table2/mean_interval_time_reduction", 0.0, mean_ratio))
    return rows


def fig5(steps: int = 40):
    """Overhead of the real profiler attached to a toy training loop."""
    import jax
    import jax.numpy as jnp

    def loop(profile: bool):
        reg = SiteRegistry()
        mgr = ArenaManager(reg, promotion_threshold=1024)
        sites = [reg.register([f"w{i}"], SiteKind.PARAM) for i in range(64)]
        for s in sites:
            mgr.allocate(s, 1 << 20)
        profiler = OnlineProfiler(mgr, CLX)
        x = jnp.ones((1024, 1024), jnp.float32)

        @jax.jit
        def step(x):
            return x @ x * (1.0 / 1024.0) + 1.0

        step(x).block_until_ready()
        t0 = time.perf_counter()
        for i in range(steps):
            x = step(x)
            if profile:
                # Access-model updates every step; profile snapshot at the
                # decision interval (1 per 10 steps, mirroring 10s/step-time).
                for s in sites:
                    mgr.touch(s, 1000)
                if i % 10 == 9:
                    profiler.snapshot()
        x.block_until_ready()
        return time.perf_counter() - t0

    base = min(loop(False) for _ in range(3))
    prof = min(loop(True) for _ in range(3))
    return [("fig5/online_profiler_overhead", prof * 1e6, prof / base)]


def run(quick: bool = False):
    return emit(table2(quick) + fig5())


if __name__ == "__main__":
    run()
