"""Kernel micro-benchmarks: Pallas (interpret) vs jnp oracle wall time on
CPU — correctness-weighted timing only (TPU wall-time is the target, not
measurable here); ``derived`` = max abs error vs the oracle."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec

from repro.dist.collectives import (
    ragged_all_to_all_reference,
    ring_ragged_all_to_all,
    shard_map_compat,
)
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.moe_gemm import moe_grouped_ffn_pallas
from repro.kernels.paged_attention import paged_attention_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas
from repro.launch.mesh import compat_make_mesh

from .common import emit


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / reps * 1e6


def run(quick: bool = False):
    rows = []
    ks = jax.random.split(jax.random.PRNGKey(0), 8)

    # flash attention
    B, S, H, K, dh = 2, 256, 8, 4, 64
    q = jax.random.normal(ks[0], (B, S, H, dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, dh), jnp.float32)
    want, us_ref = _time(lambda a, b, c: ref.mha_reference(a, b, c), q, k, v)
    got, us_pal = _time(
        lambda a, b, c: flash_attention_pallas(a, b, c, True, None, True),
        q, k, v)
    err = float(jnp.abs(got - want).max())
    rows.append(("kernels/flash_attention/oracle", us_ref, 0.0))
    rows.append(("kernels/flash_attention/pallas_interpret", us_pal, err))

    # paged attention
    rng = np.random.default_rng(0)
    B, H, K, dh, N, P, MP = 4, 8, 4, 64, 32, 16, 8
    q1 = jnp.asarray(rng.normal(size=(B, H, dh)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(N, P, K, dh)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(N, P, K, dh)), jnp.float32)
    table = jnp.asarray(
        rng.permutation(N)[: B * MP].reshape(B, MP), jnp.int32)
    lengths = jnp.asarray(rng.integers(P, MP * P, B), jnp.int32)
    want, us_ref = _time(ref.paged_attention_reference, q1, kp, vp, table,
                         lengths)
    got, us_pal = _time(
        lambda *a: paged_attention_pallas(*a, interpret=True),
        q1, kp, vp, table, lengths)
    err = float(jnp.abs(got - want).max())
    rows.append(("kernels/paged_attention/oracle", us_ref, 0.0))
    rows.append(("kernels/paged_attention/pallas_interpret", us_pal, err))

    # ssd scan
    B, Q, H, P_, N_ = 2, 128, 8, 64, 64
    x = jnp.asarray(rng.normal(size=(B, Q, H, P_)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (B, Q, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, Q, N_)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, Q, N_)), jnp.float32)
    want, us_ref = _time(ref.ssd_reference, x, dt, A, Bm, Cm)
    got, us_pal = _time(lambda *a: ssd_scan_pallas(*a, interpret=True),
                        x, dt, A, Bm, Cm)
    err = float(jnp.abs(got - want).max())
    rows.append(("kernels/ssd_scan/oracle", us_ref, 0.0))
    rows.append(("kernels/ssd_scan/pallas_interpret", us_pal, err))

    # grouped-expert GEMM (dropless MoE dispatch): ragged per-expert
    # segments with an empty group, tile-straddling boundaries included.
    E, d, f = (4, 64, 128) if quick else (8, 128, 512)
    sizes = rng.integers(0, 96, E)
    sizes[0] = 0
    sizes[-1] = max(int(sizes[-1]), 1)
    T = int(sizes.sum())
    gs = jnp.asarray(sizes, jnp.int32)
    xg = jnp.asarray(rng.normal(size=(T, d)), jnp.float32)
    wg = jnp.asarray(rng.normal(size=(E, d, f)) * 0.1, jnp.float32)
    wu = jnp.asarray(rng.normal(size=(E, d, f)) * 0.1, jnp.float32)
    wd = jnp.asarray(rng.normal(size=(E, f, d)) * 0.1, jnp.float32)
    want, us_ref = _time(ref.moe_grouped_ffn_reference, xg, wg, wu, wd, gs)
    got, us_pal = _time(
        lambda *a: moe_grouped_ffn_pallas(*a, block_t=64, block_f=128,
                                          interpret=True),
        xg, wg, wu, wd, gs)
    err = float(jnp.abs(got - want).max())
    rows.append(("kernels/moe_grouped_gemm/oracle", us_ref, 0.0))
    rows.append(("kernels/moe_grouped_gemm/pallas_interpret", us_pal, err))

    # ragged all-to-all (dropless ep MoE dispatch): ring ppermute
    # decomposition vs the dense all-gather oracle, over however many
    # devices this process has (CI's 8-device job makes it a real
    # exchange; on one device it degenerates to the local copy).
    n = jax.device_count()
    mesh = compat_make_mesh((n,), ("model",))
    R, dr = (32, 64) if quick else (128, 256)
    sizes = rng.integers(1, max(R // n, 2), (n, n)).astype(np.int32)
    if n > 1:
        sizes[0, :] = 0                      # an empty-send shard
    payload = jnp.asarray(rng.normal(size=(n, R, dr)), jnp.float32)
    send = jnp.asarray(sizes)
    recv = jnp.asarray(np.ascontiguousarray(sizes.T))

    def _a2a(fn):
        def body(rows_blk, send_blk, recv_blk):
            return fn(rows_blk[0], send_blk[0], recv_blk[0], "model",
                      chunk_rows=R, out_rows=n * R)[None]
        spec = PartitionSpec("model")
        return jax.jit(shard_map_compat(
            body, mesh, in_specs=(spec, spec, spec), out_specs=spec))

    want, us_ref = _time(_a2a(ragged_all_to_all_reference), payload, send,
                         recv)
    got, us_ring = _time(_a2a(ring_ragged_all_to_all), payload, send, recv)
    err = float(jnp.abs(got - want).max())
    rows.append(("kernels/ragged_all_to_all/dense_oracle", us_ref, 0.0))
    rows.append(("kernels/ragged_all_to_all/ring", us_ring, err))
    return emit(rows)


if __name__ == "__main__":
    run()
