"""Fig. 6 reproduction: throughput of first-touch / offline / online guided
tiering under DRAM capacity limits of 10-50% of peak RSS, relative to the
unconstrained default.  ``derived`` = throughput relative to default."""

from __future__ import annotations

from repro.core import CLX
from repro.mem import MemorySimulator
from repro.mem.workloads import CORAL, SPEC

from .common import emit, timed

CAPS = (0.10, 0.20, 0.30, 0.40, 0.50)


def run(quick: bool = False):
    rows = []
    coral = list(CORAL.items())
    spec = list(SPEC.items())
    caps = CAPS if not quick else (0.20, 0.50)
    for name, wlf in coral:
        wl = wlf("medium")
        sim = MemorySimulator(CLX, wl)
        default = sim.run_all_fast()
        for cap_frac in caps:
            cap = int(wl.peak_rss * cap_frac)
            for policy, runner in (
                ("first_touch", lambda: sim.run_first_touch(cap)),
                ("offline", lambda: sim.run_offline(cap)),
                ("online", lambda: sim.run_online(cap)),
            ):
                res, us = timed(runner)
                rows.append(
                    (
                        f"fig6/{wl.name}/{int(cap_frac*100)}pct/{policy}",
                        us,
                        res.throughput / default.throughput,
                    )
                )
    for name, wlf in spec:
        wl = wlf()
        sim = MemorySimulator(CLX, wl)
        default = sim.run_all_fast()
        for cap_frac in (caps if not quick else (0.20,)):
            cap = int(wl.peak_rss * cap_frac)
            for policy, runner in (
                ("first_touch", lambda: sim.run_first_touch(cap)),
                ("offline", lambda: sim.run_offline(cap)),
                ("online", lambda: sim.run_online(cap)),
            ):
                res, us = timed(runner)
                rows.append(
                    (
                        f"fig6/{wl.name}/{int(cap_frac*100)}pct/{policy}",
                        us,
                        res.throughput / default.throughput,
                    )
                )
    return emit(rows)


if __name__ == "__main__":
    run()
