"""Serving-engine benchmark: guided KV-page tiering (the paper's technique
applied to serving) vs LRU/FIFO eviction on a multi-session workload with an
HBM page budget, plus a prefill-throughput case comparing one-shot paged
prefill (a single jitted dispatch per prompt) against the chunked per-token
oracle.  ``derived`` = page-swap bytes moved (lower is better) for swap
rows, modeled step time (PCIe swaps + decode) for time rows, prompt tokens/s
for prefill-throughput rows and seconds for time-to-first-token rows."""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.core import TPU_V5E
from repro.launch.analysis import serving_summary
from repro.models import build_model
from repro.serve import Engine, ServeConfig

from .common import emit


def _smoke_model():
    cfg = dataclasses.replace(get_smoke("llama3_2_1b"), remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def session_workload(policy: str, rounds: int = 10):
    """Hot multi-turn sessions + periodic one-shot 'scan' sessions (long
    prompt, generated once, never resumed) — the access pattern where
    frequency-aware guidance must resist cache pollution."""
    cfg, model, params = _smoke_model()
    eng = Engine(model, params, ServeConfig(
        max_batch=2, page_size=4, hbm_pages=12, host_pages=160,
        policy=policy, interval_steps=4))
    rng = np.random.default_rng(0)
    prompt = [2, 7, 1, 8, 2, 8]
    for rid in range(4):
        eng.add_request(rid, prompt, max_new=64)
        eng.pause(rid)
    hot = [0, 1]
    scan_id = 1000
    t0 = time.perf_counter()
    for r in range(rounds):
        for rid in hot:
            eng.resume(rid)
        if r % 5 == 4:
            eng.resume(2 + (r // 5) % 2)
        for _ in range(2):
            eng.step()
        if r % 2 == 1:
            # scan: long one-shot request, decoded briefly, then abandoned
            long_prompt = [int(t) for t in rng.integers(1, cfg.vocab, 16)]
            eng.add_request(scan_id, long_prompt, max_new=2)
            eng.step()
            eng.step()
            scan_id += 1
        for rid in list(eng.requests):
            if eng.requests[rid].state == "active":
                eng.pause(rid)
    wall = time.perf_counter() - t0
    return serving_summary(eng), wall


def prefill_throughput(mode: str, prompt_len: int):
    """Prompt-ingestion cost for one prefill mode: prompt tokens/s of the
    ingest itself and wall-clock time-to-first-token (ingest + one decode
    step), measured after a warm-up request compiles both paths."""
    _, model, params = _smoke_model()
    eng = Engine(model, params, ServeConfig(
        max_batch=2, page_size=4, hbm_pages=64, host_pages=64,
        policy="gdt", interval_steps=8, prefill=mode,
        max_pages_per_seq=max(32, prompt_len // 4 + 2)))
    rng = np.random.default_rng(1)
    warm = [int(t) for t in rng.integers(1, 256, prompt_len)]
    eng.add_request(0, warm, max_new=1)           # compile
    while 0 in eng.requests:
        eng.step()
    prompt = [int(t) for t in rng.integers(1, 256, prompt_len)]
    d0 = eng.prefill_dispatches
    t0 = time.perf_counter()
    eng.add_request(1, prompt, max_new=2)
    # Block on the KV pools: the one-shot path is a single async jitted
    # dispatch, so without a sync the timer would measure dispatch
    # overhead, not the ingest itself (chunked syncs every token anyway).
    jax.block_until_ready((eng.pool.k_hbm, eng.pool.v_hbm))
    t_ingest = time.perf_counter() - t0
    first = None
    while first is None:
        out = eng.step()
        first = out.get(1)
    ttft = time.perf_counter() - t0
    dispatches = eng.prefill_dispatches - d0
    tokens_per_s = (prompt_len - 1) / t_ingest if t_ingest else float("inf")
    return tokens_per_s, ttft, dispatches, t_ingest


def run(quick: bool = False):
    rows = []
    pcie = TPU_V5E.slow.read_bw_GBps * 1e9
    for policy in ("gdt", "lru", "fifo"):
        summary, wall = session_workload(policy, rounds=6 if quick else 10)
        bytes_moved = summary["engine_bytes_moved"]
        swap_s = bytes_moved / pcie
        rows.append((f"serve/{policy}/swap_bytes", wall * 1e6, bytes_moved))
        rows.append((f"serve/{policy}/swap_ins", wall * 1e6,
                     summary["engine_swap_ins"]))
        rows.append((f"serve/{policy}/modeled_swap_seconds", wall * 1e6,
                     swap_s))
        rows.append((f"serve/{policy}/transfer_events", wall * 1e6,
                     summary["engine_transfer_events"]))
        rows.append((f"serve/{policy}/preemptions", wall * 1e6,
                     summary["engine_preemptions"]))
        if "migrations" in summary:  # the controller's own event stream
            rows.append((f"serve/{policy}/guided_migrations", wall * 1e6,
                         summary["migrations"]))
            rows.append((f"serve/{policy}/guided_rental_bytes", wall * 1e6,
                         summary["rental_bytes"]))
            rows.append((f"serve/{policy}/dropped_promotions", wall * 1e6,
                         summary["dropped_promotions"]))
    prompt_len = 32 if quick else 96
    for mode in ("one_shot", "chunked"):
        tps, ttft, dispatches, t_ingest = prefill_throughput(mode, prompt_len)
        rows.append((f"serve/prefill/{mode}/tokens_per_s",
                     t_ingest * 1e6, tps))
        rows.append((f"serve/prefill/{mode}/ttft_seconds",
                     ttft * 1e6, ttft))
        rows.append((f"serve/prefill/{mode}/dispatches",
                     t_ingest * 1e6, dispatches))
    return emit(rows)


if __name__ == "__main__":
    run()
