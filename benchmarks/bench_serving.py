"""Serving-engine benchmark: guided KV-page tiering (the paper's technique
applied to serving) vs LRU/FIFO eviction on a multi-session workload with an
HBM page budget, a prefill-throughput case comparing one-shot paged prefill
(a single jitted dispatch per prompt) against the chunked per-token oracle,
a generation-API case measuring in-dispatch sampling overhead (sampled
vs greedy decode tokens/s) plus streaming time-to-first-delta through
``LLM.submit``, a prefix-cache sweep measuring TTFT on a
shared-system-prompt workload as the cached share of the prompt rises,
kill-a-replica chaos, and an SLO replay case: a deterministic two-tenant
bursty trace (``serve.workload``) replayed with one-shot vs interleaved
chunked prefill, scored as p50/p99 TTFT/TPOT and goodput-under-SLO on the
modeled step clock with a bitwise-vs-unloaded stream check.
``derived`` = page-swap bytes moved (lower is better) for swap rows,
modeled step time (PCIe swaps + decode) for time rows, prompt tokens/s for
prefill-throughput rows, seconds for TTFT rows, decode tokens/s for
sampled-decode rows, counts for finish-reason rows, hit-rate /
saved-token figures for the prefix sweep, and modeled-ms latencies /
goodput fractions / a 0-or-1 bitwise flag for the SLO replay rows."""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.core import TPU_V5E
from repro.launch.analysis import serving_summary
from repro.models import build_model
from repro.serve import (
    LLM,
    SLO,
    SamplingParams,
    ServeConfig,
    TenantSpec,
    TraceReplayer,
    WorkloadConfig,
    synthesize,
)

from .common import emit


def _smoke_model():
    cfg = dataclasses.replace(get_smoke("llama3_2_1b"), remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def session_workload(policy: str, rounds: int = 10):
    """Hot multi-turn sessions + periodic one-shot 'scan' sessions (long
    prompt, generated once, never resumed) — the access pattern where
    frequency-aware guidance must resist cache pollution.  Driven entirely
    through the ``LLM`` front door."""
    cfg, model, params = _smoke_model()
    llm = LLM(model, params, ServeConfig(
        max_batch=2, page_size=4, hbm_pages=12, host_pages=160,
        policy=policy, interval_steps=4))
    rng = np.random.default_rng(0)
    prompt = [2, 7, 1, 8, 2, 8]
    for rid in range(4):
        llm.submit(prompt, SamplingParams(max_tokens=64), request_id=rid)
        llm.pause(rid)
    hot = [0, 1]
    scan_id = 1000
    t0 = time.perf_counter()
    for r in range(rounds):
        for rid in hot:
            if llm.is_live(rid):
                llm.resume(rid)
        extra = 2 + (r // 5) % 2
        if r % 5 == 4 and llm.is_live(extra):
            llm.resume(extra)
        for _ in range(2):
            llm.step()
        if r % 2 == 1:
            # scan: long one-shot request, decoded briefly, then abandoned
            long_prompt = [int(t) for t in rng.integers(1, cfg.vocab, 16)]
            llm.submit(long_prompt, SamplingParams(max_tokens=2),
                       request_id=scan_id)
            llm.step()
            llm.step()
            scan_id += 1
        for rid in list(llm.engine.requests):
            if llm.engine.requests[rid].state == "active":
                llm.pause(rid)
    wall = time.perf_counter() - t0
    return serving_summary(llm.engine), wall


def prefill_throughput(mode: str, prompt_len: int):
    """Prompt-ingestion cost for one prefill mode: prompt tokens/s of the
    ingest itself and wall-clock time-to-first-token (ingest + one decode
    step), measured after a warm-up request compiles both paths."""
    _, model, params = _smoke_model()
    llm = LLM(model, params, ServeConfig(
        max_batch=2, page_size=4, hbm_pages=64, host_pages=64,
        policy="gdt", interval_steps=8, prefill=mode,
        max_pages_per_seq=max(32, prompt_len // 4 + 2)))
    eng = llm.engine
    rng = np.random.default_rng(1)
    warm = [int(t) for t in rng.integers(1, 256, prompt_len)]
    llm.submit(warm, SamplingParams(max_tokens=1), request_id=0)  # compile
    while llm.is_live(0):
        llm.step()
    prompt = [int(t) for t in rng.integers(1, 256, prompt_len)]
    d0 = eng.prefill_dispatches
    t0 = time.perf_counter()
    handle = llm.submit(prompt, SamplingParams(max_tokens=2), request_id=1)
    # Block on the KV pools: the one-shot path is a single async jitted
    # dispatch, so without a sync the timer would measure dispatch
    # overhead, not the ingest itself (chunked syncs every token anyway).
    jax.block_until_ready((eng.pool.k_hbm, eng.pool.v_hbm))
    t_ingest = time.perf_counter() - t0
    handle.next_delta()                   # streaming first token
    ttft = time.perf_counter() - t0
    dispatches = eng.prefill_dispatches - d0
    tokens_per_s = (prompt_len - 1) / t_ingest if t_ingest else float("inf")
    return tokens_per_s, ttft, dispatches, t_ingest


def sampled_decode(temperature: float, n_requests: int = 4,
                   max_tokens: int = 16):
    """Generation-API decode throughput at one temperature: submit
    ``n_requests`` streaming handles, record time-to-first-delta on the
    first, then drain everything.  ``temperature=0`` is the greedy
    baseline the sampled run's overhead is reported against."""
    _, model, params = _smoke_model()
    llm = LLM(model, params, ServeConfig(
        max_batch=4, page_size=4, hbm_pages=48, host_pages=64,
        policy="gdt", interval_steps=8))
    rng = np.random.default_rng(2)
    prompts = [[int(t) for t in rng.integers(1, 256, 8)]
               for _ in range(n_requests)]
    sp = [SamplingParams(temperature=temperature, top_k=40, top_p=0.9,
                         seed=i, max_tokens=max_tokens)
          for i in range(n_requests)]
    # Warm-up: compile the decode dispatch for this batch shape.
    llm.generate(prompts[0], SamplingParams(temperature=temperature,
                                            top_k=40, top_p=0.9,
                                            max_tokens=2))
    # Finish-reason counters are monotonic: baseline after the warm-up so
    # the emitted counts cover exactly the measured requests.
    base = llm.stats()
    t0 = time.perf_counter()
    handles = [llm.submit(p, s) for p, s in zip(prompts, sp)]
    handles[0].next_delta()
    ttfd = time.perf_counter() - t0
    outs = [h.result() for h in handles]
    wall = time.perf_counter() - t0
    tokens = sum(len(o.token_ids) for o in outs)
    stats = llm.stats()
    reasons = {r: stats[f"finished_{r}"] - base[f"finished_{r}"]
               for r in ("stop", "length", "truncated")}
    return tokens / wall, ttfd, reasons, wall


def prefix_share_ttft(share: float, prompt_len: int, page_size: int = 4):
    """TTFT on a shared-system-prompt workload at one prefix share.

    A seeder request populates the radix cache with the shared prefix
    (``share`` of the prompt, page-aligned); a warm-up request with the
    same share compiles the suffix's jit bucket AND exercises the hit path;
    the measured request then covers ``share`` of its prompt from the cache
    and prefills only the suffix — TTFT should fall ~linearly as the share
    rises (a full hit skips the prefill dispatch entirely)."""
    _, model, params = _smoke_model()
    llm = LLM(model, params, ServeConfig(
        max_batch=2, page_size=page_size, hbm_pages=64, host_pages=64,
        policy="gdt", interval_steps=8, enable_prefix_cache=True,
        max_pages_per_seq=max(32, prompt_len // page_size + 2)))
    eng = llm.engine
    rng = np.random.default_rng(3)
    # Page-align the shared span: sharing is full-page granular.
    shared_pages = int(share * prompt_len) // page_size
    n_shared = shared_pages * page_size
    shared_prefix = [int(t) for t in rng.integers(1, 256, n_shared)]

    def prompt_with_tail(seed: int):
        tail = [int(t) for t in
                np.random.default_rng(seed).integers(1, 256,
                                                     prompt_len - n_shared)]
        return shared_prefix + tail

    for rid, seed in ((0, 100), (1, 101)):     # seeder, then bucket warm-up
        llm.submit(prompt_with_tail(seed), SamplingParams(max_tokens=1),
                   request_id=rid)
        while llm.is_live(rid):
            llm.step()
    base_saved = eng.saved_prefill_tokens
    # Best-of-3 distinct-tail trials: CPU dispatch jitter is the same order
    # as a short suffix's ingest, so a single sample can invert the trend.
    ttft = float("inf")
    for trial, seed in enumerate((102, 103, 104)):
        t0 = time.perf_counter()
        handle = llm.submit(prompt_with_tail(seed),
                            SamplingParams(max_tokens=2),
                            request_id=2 + trial)
        jax.block_until_ready((eng.pool.k_hbm, eng.pool.v_hbm))
        handle.next_delta()
        ttft = min(ttft, time.perf_counter() - t0)
        while llm.is_live(2 + trial):
            llm.step()
    saved = (eng.saved_prefill_tokens - base_saved) / 3
    return ttft, eng.prefix_cache.hit_rate, saved


def cluster_chaos(n_replicas: int = 3, n_requests: int = 9,
                  max_tokens: int = 6, kill_step: int = 3,
                  heartbeat_timeout: float = 2.0):
    """Kill-a-replica chaos under continuous submit load: one of
    ``n_replicas`` replicas crashes mid-decode, the router detects the
    missed heartbeats and cold-migrates its in-flight requests to the
    survivors.  Reports requests dropped (the zero-drop contract), p99
    time-to-first-token across the run (the failover window shows up as
    the TTFT tail of requests stalled on the dead replica), and the
    migration counters."""
    _, model, params = _smoke_model()
    llm = LLM(model, params, ServeConfig(
        max_batch=2, page_size=4, hbm_pages=24, host_pages=64,
        policy="gdt", interval_steps=8), replicas=n_replicas,
        heartbeat_timeout=heartbeat_timeout)
    rng = np.random.default_rng(5)
    handles, submit_t, first_t = {}, {}, {}
    next_rid = 0

    def submit_one():
        nonlocal next_rid
        prompt = [int(t) for t in rng.integers(1, 256, 6)]
        submit_t[next_rid] = time.perf_counter()
        handles[next_rid] = llm.submit(
            prompt, SamplingParams(max_tokens=max_tokens),
            request_id=next_rid)
        next_rid += 1

    for _ in range(n_replicas):
        submit_one()
    killed = False
    steps = 0
    while (next_rid < n_requests
           or any(not h.finished for h in handles.values())):
        if steps == kill_step and not killed:
            llm.cluster.fail(llm.cluster.replicas[0].replica_id)
            killed = True
        if next_rid < n_requests and steps % 2 == 0:
            submit_one()
        llm.step()
        now = time.perf_counter()
        for rid, h in handles.items():
            if rid not in first_t and h.token_ids:
                first_t[rid] = now
        steps += 1
        if steps > 500:      # chaos must converge; a hang is a bug signal
            break
    dropped = sum(1 for h in handles.values() if not h.finished)
    ttfts = sorted(first_t[rid] - submit_t[rid] for rid in first_t)
    p99 = float(np.percentile(ttfts, 99)) if ttfts else float("inf")
    stats = llm.stats()
    return dropped, p99, stats


def _moe_smoke_model():
    """The MoE smoke config at 4 layers instead of 2.  Layer-ahead expert
    prefetch can never predict the wrap-around dispatch (the next step's
    first layer routes a token that does not exist yet), so the 2-layer
    smoke stack would charge HALF of all dispatches to that blind spot —
    the real granite_moe config has 32 layers, where it is 1/32.  Four
    layers keep the CPU cost small without the pathological handicap."""
    cfg = dataclasses.replace(get_smoke("granite_moe_3b_a800m"),
                              remat=False, n_layers=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def expert_tiering_decode(cache_frac: float, double_buffer: bool,
                          rounds: int):
    """Decode throughput with MoE expert FFN weights host-resident behind
    a bounded HBM cache holding ``cache_frac`` of all (layer, expert)
    blocks, with or without the double-buffered speculative prefetch.
    Rotating session pairs churn the routed expert mix so the cache
    actually turns over.  Returns wall tokens/s plus the store's modeled
    clock: ``m_blocked_s`` is the decode time spent waiting on host->HBM
    weight transfers — the number the prefetch exists to shrink."""
    cfg, model, params = _moe_smoke_model()
    total = cfg.n_layers * cfg.n_experts
    C = max(4, int(round(total * cache_frac)))
    llm = LLM(model, params, ServeConfig(
        max_batch=2, page_size=4, hbm_pages=48, host_pages=96,
        policy="gdt", interval_steps=16, prefill_chunk_tokens=2,
        expert_offchip=True, expert_cache_size=C,
        expert_double_buffer=double_buffer))
    store = llm.engine.expert_store
    rng = np.random.default_rng(9)
    n_sessions = 6
    for rid in range(n_sessions):
        prompt = [int(t) for t in rng.integers(1, cfg.vocab, 6)]
        llm.submit(prompt, SamplingParams(
            temperature=0.8, top_k=4, seed=rid,
            max_tokens=4 * rounds + 16), request_id=rid)
        if llm.engine.requests[rid].state == "active":
            llm.pause(rid)
    # Warm-up: compile every tiered dispatch shape, then zero the clock so
    # the measured window starts from identical resident state.
    llm.resume(0)
    llm.resume(1)
    for _ in range(4):
        llm.step()
    for rid in list(llm.engine.requests):
        if llm.engine.requests[rid].state == "active":
            llm.pause(rid)
    store.reset_counters()
    tokens = 0
    t0 = time.perf_counter()
    for r in range(rounds):
        for rid in (r % n_sessions, (r + 1) % n_sessions):
            if llm.is_live(rid):
                llm.resume(rid)
        for _ in range(3):
            tokens += len(llm.step())
        for rid in list(llm.engine.requests):
            if llm.engine.requests[rid].state == "active":
                llm.pause(rid)
    wall = time.perf_counter() - t0
    return dict(
        tokens=tokens, wall=wall, cache_slots=C,
        demand_fetches=store.demand_fetches,
        prefetch_fetches=store.prefetch_fetches,
        prefetch_hits=store.prefetch_hits,
        evictions=store.evictions,
        m_compute_s=store.m_compute_s, m_blocked_s=store.m_blocked_s)


def _slo_trace(quick: bool):
    """The smoke replay scenario: a decode-heavy 'chat' tenant (steady
    Poisson arrivals, short sampled completions) sharing the engine with a
    'batch' tenant whose bursts carry long prompts (the 32k-prefill
    problem scaled to the CPU smoke model).  Fully deterministic — one
    workload seed pins every arrival, length, and sampled stream."""
    long_prompt = 128 if quick else 256
    tenants = (
        TenantSpec(name="chat", arrival="poisson", rate=0.3,
                   prompt_mix=((6, 1.0),), output_mix=((16, 1.0),),
                   temperature=0.7),
        TenantSpec(name="batch", arrival="bursty", rate=0.05,
                   burst_factor=10.0, burst_period=16, burst_duty=0.25,
                   prompt_mix=((long_prompt, 1.0),),
                   output_mix=((2, 1.0),)),
    )
    trace = synthesize(WorkloadConfig(
        tenants=tenants, horizon_steps=32 if quick else 48, vocab=256,
        seed=8))
    return trace, long_prompt


def _slo_serve_cfg(long_prompt: int, chunk_tokens: int) -> ServeConfig:
    return ServeConfig(
        max_batch=6, page_size=8, hbm_pages=160, host_pages=64,
        policy="gdt", interval_steps=16,
        max_pages_per_seq=long_prompt // 8 + 4,
        prefill_chunk_tokens=chunk_tokens)


def _solo_reference(trace, long_prompt: int):
    """Unloaded per-request streams: each trace request runs ALONE (one
    reusable LLM, sequential submits) — sampling folds the absolute stream
    position, so any loaded schedule must reproduce these bitwise."""
    _, model, params = _smoke_model()
    llm = LLM(model, params, _slo_serve_cfg(long_prompt, 0))
    return {tr.request_id:
            llm.submit(list(tr.prompt), tr.sampling_params(),
                       request_id=tr.request_id).result().token_ids
            for tr in trace.requests}


def slo_replay(trace, long_prompt: int, chunk_tokens: int):
    """Replay the two-tenant trace at one prefill-interleaving setting and
    score it against the SLO on the modeled step clock (where a one-shot
    long prefill is VISIBLE as one 25-50x step, stalling every concurrent
    decode's inter-token gap)."""
    _, model, params = _smoke_model()
    llm = LLM(model, params, _slo_serve_cfg(long_prompt, chunk_tokens))
    slo = SLO(ttft_ms=100.0, tpot_ms=25.0)
    report = TraceReplayer(llm, trace, slo=slo).run(max_steps=2048)
    return report, slo


def run(quick: bool = False):
    rows = []
    pcie = TPU_V5E.slow.read_bw_GBps * 1e9
    for policy in ("gdt", "lru", "fifo"):
        summary, wall = session_workload(policy, rounds=6 if quick else 10)
        bytes_moved = summary["engine_bytes_moved"]
        swap_s = bytes_moved / pcie
        rows.append((f"serve/{policy}/swap_bytes", wall * 1e6, bytes_moved))
        rows.append((f"serve/{policy}/swap_ins", wall * 1e6,
                     summary["engine_swap_ins"]))
        rows.append((f"serve/{policy}/modeled_swap_seconds", wall * 1e6,
                     swap_s))
        rows.append((f"serve/{policy}/transfer_events", wall * 1e6,
                     summary["engine_transfer_events"]))
        rows.append((f"serve/{policy}/preemptions", wall * 1e6,
                     summary["engine_preemptions"]))
        if "migrations" in summary:  # the controller's own event stream
            rows.append((f"serve/{policy}/guided_migrations", wall * 1e6,
                         summary["migrations"]))
            rows.append((f"serve/{policy}/guided_rental_bytes", wall * 1e6,
                         summary["rental_bytes"]))
            rows.append((f"serve/{policy}/dropped_promotions", wall * 1e6,
                         summary["dropped_promotions"]))
    prompt_len = 32 if quick else 96
    for mode in ("one_shot", "chunked"):
        tps, ttft, dispatches, t_ingest = prefill_throughput(mode, prompt_len)
        rows.append((f"serve/prefill/{mode}/tokens_per_s",
                     t_ingest * 1e6, tps))
        rows.append((f"serve/prefill/{mode}/ttft_seconds",
                     ttft * 1e6, ttft))
        rows.append((f"serve/prefill/{mode}/dispatches",
                     t_ingest * 1e6, dispatches))
    # Prefix-cache sweep: TTFT on a shared-system-prompt workload should
    # fall ~linearly as the cached share of the prompt rises (the suffix
    # is all that prefills).  ``derived`` = seconds for ttft rows, cache
    # hit rate for hit_rate rows, prompt tokens served from the cache for
    # saved_tokens rows.
    # Long enough that ingest compute (linear in the uncovered suffix)
    # outweighs per-dispatch overhead even on the CPU smoke model.
    sweep_len = max(prompt_len, 64)
    for share in (0.0, 0.5, 1.0):
        ttft, hit_rate, saved = prefix_share_ttft(share, sweep_len)
        tag = f"serve/prefix_share/{share:.1f}"
        rows.append((f"{tag}/ttft_seconds", ttft * 1e6, ttft))
        rows.append((f"{tag}/hit_rate", 0.0, hit_rate))
        rows.append((f"{tag}/saved_tokens", 0.0, float(saved)))
    # Generation API: sampled vs greedy decode through LLM.submit handles.
    max_tokens = 8 if quick else 16
    results = {}
    for name, temp in (("greedy", 0.0), ("sampled", 0.8)):
        tps, ttfd, reasons, wall = sampled_decode(temp,
                                                  max_tokens=max_tokens)
        results[name] = tps
        rows.append((f"serve/generate/{name}/tokens_per_s", wall * 1e6, tps))
        rows.append((f"serve/generate/{name}/ttfd_seconds", ttfd * 1e6,
                     ttfd))
        for reason in ("stop", "length", "truncated"):
            rows.append((f"serve/generate/{name}/finished_{reason}",
                         wall * 1e6, reasons[reason]))
    # In-dispatch sampling overhead: greedy tokens/s over sampled tokens/s
    # (~1.0 when the Gumbel/top-k/top-p epilogue fuses cleanly).
    rows.append(("serve/generate/sampling_overhead_x", 0.0,
                 results["greedy"] / max(results["sampled"], 1e-9)))
    # Kill-a-replica chaos: the zero-drop contract under failover, with the
    # failover window visible as the p99 TTFT tail.  ``derived`` = dropped
    # requests / seconds / event counts respectively.
    dropped, p99_ttft, cstats = cluster_chaos(
        n_requests=6 if quick else 9)
    rows.append(("serve/chaos/requests_dropped", 0.0, float(dropped)))
    rows.append(("serve/chaos/p99_ttft_seconds", p99_ttft * 1e6, p99_ttft))
    rows.append(("serve/chaos/failovers", 0.0,
                 cstats["cluster_failovers"]))
    rows.append(("serve/chaos/migrations_cold", 0.0,
                 cstats["cluster_migrations_cold"]))
    rows.append(("serve/chaos/requests_lost", 0.0,
                 cstats["cluster_requests_lost"]))
    # Expert-weight tiering: MoE decode with expert FFN blocks behind a
    # bounded HBM cache, swept over the cached fraction of all blocks,
    # with (db) and without (sync) the double-buffered speculative
    # prefetch.  ``derived`` = wall decode tokens/s for tokens_per_s rows,
    # modeled seconds stalled on host->HBM weight fetches for blocked
    # rows, counts for fetch/hit rows.  The headline is
    # recovered_fraction: how much of the synchronous-fetch stall the
    # prefetch hides at cache fraction 0.5 (the acceptance bar is >= 0.5).
    ex_rounds = 8 if quick else 12
    blocked = {}
    for frac in (1.0, 0.5, 0.25):
        for db in (True, False):
            if frac == 1.0 and not db:
                continue          # everything resident: nothing to fetch
            mode = "db" if db else "sync"
            r = expert_tiering_decode(frac, db, ex_rounds)
            blocked[(frac, mode)] = r["m_blocked_s"]
            tag = f"serve/expert_tiering/frac{frac:g}/{mode}"
            rows.append((f"{tag}/decode_tokens_per_s", r["wall"] * 1e6,
                         r["tokens"] / r["wall"]))
            rows.append((f"{tag}/modeled_blocked_s", r["wall"] * 1e6,
                         r["m_blocked_s"]))
            rows.append((f"{tag}/demand_fetches", r["wall"] * 1e6,
                         r["demand_fetches"]))
            rows.append((f"{tag}/prefetch_hits", r["wall"] * 1e6,
                         r["prefetch_hits"]))
    sync_stall = blocked[(0.5, "sync")]
    rows.append(("serve/expert_tiering/frac0.5/recovered_fraction", 0.0,
                 (sync_stall - blocked[(0.5, "db")]) / sync_stall
                 if sync_stall else 0.0))
    # SLO replay: bursty two-tenant trace, FIFO one-shot vs FIFO with
    # chunked-prefill interleaving.  ``derived`` = modeled milliseconds
    # for latency rows, fractions for goodput rows, and a 0/1 flag for the
    # bitwise-vs-unloaded check; ``us_per_call`` = the replay's total
    # modeled time.  The headline is chat_p99_tpot_ms: the decode-heavy
    # tenant's worst inter-token stall under the batch tenant's
    # long-prefill bursts must IMPROVE when interleaving is on, while
    # every sampled stream stays bitwise-equal to its unloaded solo run.
    trace, long_prompt = _slo_trace(quick)
    ref_streams = _solo_reference(trace, long_prompt)
    for label, chunk in (("fifo_oneshot", 0), ("fifo_chunked", 16)):
        report, slo = slo_replay(trace, long_prompt, chunk)
        s_all = report.summary(slo=slo)
        s_chat = report.summary(tenant="chat", slo=slo)
        us = report.modeled_ms * 1e3
        tag = f"serve/slo_replay/{label}"
        rows.append((f"{tag}/p50_ttft_ms", us, s_all["p50_ttft_ms"]))
        rows.append((f"{tag}/p99_ttft_ms", us, s_all["p99_ttft_ms"]))
        rows.append((f"{tag}/p50_tpot_ms", us, s_all["p50_tpot_ms"]))
        rows.append((f"{tag}/p99_tpot_ms", us, s_all["p99_tpot_ms"]))
        rows.append((f"{tag}/chat_p99_tpot_ms", us,
                     s_chat["p99_tpot_ms"]))
        rows.append((f"{tag}/goodput_slo", us, s_all["goodput_slo"]))
        rows.append((f"{tag}/chat_goodput_slo", us,
                     s_chat["goodput_slo"]))
        rows.append((f"{tag}/streams_bitwise_equal", 0.0, float(
            all(report.token_ids.get(rid) == toks
                for rid, toks in ref_streams.items()))))
    return emit(rows)


if __name__ == "__main__":
    run()
