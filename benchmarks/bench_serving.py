"""Serving-engine benchmark: guided KV-page tiering (the paper's technique
applied to serving) vs LRU/FIFO eviction on a multi-session workload with an
HBM page budget.  ``derived`` = page-swap bytes moved (lower is better) for
swap rows, and modeled step time (PCIe swaps + decode) for time rows."""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.core import TPU_V5E
from repro.launch.analysis import guidance_summary
from repro.models import build_model
from repro.serve import Engine, ServeConfig

from .common import emit


def session_workload(policy: str, rounds: int = 10):
    """Hot multi-turn sessions + periodic one-shot 'scan' sessions (long
    prompt, generated once, never resumed) — the access pattern where
    frequency-aware guidance must resist cache pollution."""
    cfg = dataclasses.replace(get_smoke("llama3_2_1b"), remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, ServeConfig(
        max_batch=2, page_size=4, hbm_pages=12, host_pages=160,
        policy=policy, interval_steps=4))
    rng = np.random.default_rng(0)
    prompt = [2, 7, 1, 8, 2, 8]
    for rid in range(4):
        eng.add_request(rid, prompt, max_new=64)
        eng.pause(rid)
    hot = [0, 1]
    scan_id = 1000
    t0 = time.perf_counter()
    for r in range(rounds):
        for rid in hot:
            eng.resume(rid)
        if r % 5 == 4:
            eng.resume(2 + (r // 5) % 2)
        for _ in range(2):
            eng.step()
        if r % 2 == 1:
            # scan: long one-shot request, decoded briefly, then abandoned
            long_prompt = [int(t) for t in rng.integers(1, cfg.vocab, 16)]
            eng.add_request(scan_id, long_prompt, max_new=2)
            eng.step()
            eng.step()
            scan_id += 1
        for rid in list(eng.requests):
            if eng.requests[rid].state == "active":
                eng.pause(rid)
    wall = time.perf_counter() - t0
    guidance = (guidance_summary(eng.runtime.events)
                if eng.runtime is not None else None)
    return eng.stats(), wall, guidance


def run(quick: bool = False):
    rows = []
    pcie = TPU_V5E.slow.read_bw_GBps * 1e9
    for policy in ("gdt", "lru", "fifo"):
        stats, wall, guidance = session_workload(
            policy, rounds=6 if quick else 10)
        swap_s = stats["bytes_moved"] / pcie
        rows.append((f"serve/{policy}/swap_bytes", wall * 1e6,
                     stats["bytes_moved"]))
        rows.append((f"serve/{policy}/swap_ins", wall * 1e6,
                     stats["swap_ins"]))
        rows.append((f"serve/{policy}/modeled_swap_seconds", wall * 1e6,
                     swap_s))
        if guidance is not None:  # the controller's own event stream
            rows.append((f"serve/{policy}/guided_migrations", wall * 1e6,
                         guidance["migrations"]))
            rows.append((f"serve/{policy}/guided_rental_bytes", wall * 1e6,
                         guidance["rental_bytes"]))
            rows.append((f"serve/{policy}/dropped_promotions", wall * 1e6,
                         guidance["dropped_promotions"]))
    return emit(rows)


if __name__ == "__main__":
    run()
