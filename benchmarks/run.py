"""Benchmark registry — one function per paper table/figure (plus framework
benches added alongside their subsystems).  Prints ``name,us_per_call,derived``
CSV rows.

Usage:
    PYTHONPATH=src python -m benchmarks.run             # everything
    PYTHONPATH=src python -m benchmarks.run --quick     # reduced sweep
    PYTHONPATH=src python -m benchmarks.run --only fig6 # one group
"""

from __future__ import annotations

import argparse
import importlib
import sys
import traceback

BENCHES = {
    "fig6": "benchmarks.bench_capacity_sweep",
    "fig7": "benchmarks.bench_migration_trace",
    "fig8": "benchmarks.bench_large_mem",
    "table2": "benchmarks.bench_profile_overhead",
    "kernels": "benchmarks.bench_kernels",
    "serve": "benchmarks.bench_serving",
    "train": "benchmarks.bench_train",
    "roofline": "benchmarks.roofline",
}

# Smallest set that exercises every Algorithm-1 backend (simulator, paged
# KV serving — including the one-shot vs chunked prefill-throughput case —
# trainer arenas) plus the Pallas kernel sweep (grouped-expert GEMM
# included) — the CI job that keeps perf scripts alive.
SMOKE_GROUPS = ("fig7", "serve", "train", "kernels")


def main() -> None:
    parser = argparse.ArgumentParser()
    parser.add_argument("--only", type=str, default=None,
                        help="comma-separated bench group names")
    parser.add_argument("--quick", action="store_true",
                        help="reduced sweeps for CI")
    parser.add_argument("--smoke", action="store_true",
                        help="CI smoke: quick mode over one bench per "
                             "guidance backend; persists each group's rows "
                             "as BENCH_<group>.json (rows + git rev + "
                             "timestamp)")
    args = parser.parse_args()

    if args.smoke:
        args.quick = True
        if args.only is None:
            args.only = ",".join(SMOKE_GROUPS)
    names = list(BENCHES) if args.only is None else args.only.split(",")
    print("name,us_per_call,derived")
    failures = []
    for name in names:
        modname = BENCHES.get(name)
        if modname is None:
            print(f"unknown bench group: {name}", file=sys.stderr)
            failures.append(name)
            continue
        try:
            mod = importlib.import_module(modname)
        except ModuleNotFoundError:
            # Subsystem not built yet / optional.
            print(f"# skip {name}: module {modname} not present", file=sys.stderr)
            continue
        try:
            rows = mod.run(quick=args.quick)
        except Exception:
            traceback.print_exc()
            failures.append(name)
            continue
        if args.smoke and rows:
            # Persist the trajectory under the module's short name
            # (bench_serving -> BENCH_serving.json) — rows + git rev +
            # timestamp, uploaded as a CI artifact.
            from .common import write_bench_json
            short = modname.rsplit(".", 1)[-1]
            short = short[len("bench_"):] if short.startswith("bench_") \
                else short
            path = write_bench_json(short, rows)
            print(f"# wrote {path}", file=sys.stderr)
    if failures:
        sys.exit(f"benchmark groups failed: {failures}")


if __name__ == "__main__":
    main()
