"""Roofline analysis (deliverable g).

Reads the dry-run artifacts (results/dryrun/pod256/*.json) and derives, per
(arch x shape) cell, the three roofline terms on TPU v5e:

    compute    = FLOPs_global        / (chips * 197e12 FLOP/s)
    memory     = HBM_bytes_global    / (chips * 819e9 B/s)
    collective = ICI_bytes_global    / (chips * 50e9 B/s per link)

Sources (see repro/launch/analysis.py): FLOPs and bytes come from the exact
loop-aware jaxpr walk (XLA's cost_analysis counts while bodies once — we
verified and worked around it); HBM traffic uses the post-fusion estimate
``bytes_dot`` (operands/outputs of dot/gather/scatter/scan-carried tensors;
fused elementwise chains do not hit HBM); collective bytes come from the
partitioned HLO with while-loop trip-count expansion (per-device payload,
multiplied by chips to match the formula's numerator).

Also reports MODEL_FLOPS (6*N*D train / 2*N*D prefill / 2*N*B decode, with
N = active params for MoE) and the usefulness ratio MODEL/HLO.

``derived`` column in CSV mode = roofline fraction (compute / dominant).
Run with --markdown to emit the EXPERIMENTS.md table.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, Optional

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # B/s per chip
ICI_BW = 50e9            # B/s per link

RESULTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results", "dryrun")

_PARAM_CACHE: Dict[str, Dict[str, float]] = {}


def _param_counts(arch: str) -> Dict[str, float]:
    if arch in _PARAM_CACHE:
        return _PARAM_CACHE[arch]
    from repro.configs import get
    from repro.models import build_model
    from repro.models.common import count_params
    import numpy as np

    cfg = get(arch)
    model = build_model(cfg)
    defs = model.param_defs()
    total = count_params(defs)
    active = total
    if cfg.family == "moe":
        expert = count_params({k: v for k, v in defs["layers"].items()
                               if k == "moe"})
        from repro.models.moe import MoEConfig
        E, k = model.moe_cfg.padded_experts, cfg.top_k
        router = cfg.d_model * E * cfg.n_layers
        expert_only = expert - router
        active = total - expert_only * (1 - k / E)
    _PARAM_CACHE[arch] = {"total": total, "active": active}
    return _PARAM_CACHE[arch]


def model_flops(arch: str, shape: str, rec: dict) -> float:
    from repro.models.config import SHAPES

    sc = SHAPES[shape]
    n = _param_counts(arch)["active"]
    if sc.kind == "train":
        return 6.0 * n * sc.seq_len * sc.global_batch
    if sc.kind == "prefill":
        return 2.0 * n * sc.seq_len * sc.global_batch
    return 2.0 * n * sc.global_batch          # decode: per new token


def analyse_cell(rec: dict) -> Optional[dict]:
    if rec.get("status") != "ok":
        return None
    chips = rec["devices"]
    g = rec["global_cost"]
    coll_dev = sum(v["bytes"] for v in rec["collectives"].values())
    compute_s = g["flops"] / (chips * PEAK_FLOPS)
    memory_s = g["bytes_dot"] / (chips * HBM_BW)
    collective_s = coll_dev * chips / (chips * ICI_BW)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"], rec)
    frac = compute_s / max(terms.values()) if max(terms.values()) > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops": g["flops"],
        "useful_ratio": mf / g["flops"] if g["flops"] else 0.0,
        "roofline_fraction": frac,
        "temp_gb": rec["memory"]["temp_bytes"] / 2**30,
        "arg_gb": rec["memory"]["argument_bytes"] / 2**30,
        "compile_s": rec.get("compile_seconds", 0.0),
    }


FIX_HINTS = {
    "compute": "already compute-bound: raise MXU utilization "
               "(tile alignment, bf16 accumulation, fused kernels)",
    "memory": "cut HBM traffic: fuse/remat less, larger attention blocks, "
              "bf16 moments, flash kernels",
    "collective": "reshard to cut resharding collectives / overlap "
                  "(ring collectives), hierarchical DP reduction",
}


def load(mesh: str = "pod256") -> list:
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, mesh, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        row = analyse_cell(rec)
        if row:
            rows.append(row)
    return rows


def run(quick: bool = False):
    """Benchmark-registry entry: CSV rows (name, compile_us, derived)."""
    rows = load("pod256")
    out = []
    for r in rows:
        out.append((f"roofline/{r['arch']}/{r['shape']}",
                    r["compile_s"] * 1e6, r["roofline_fraction"]))
    from .common import emit

    return emit(out)


def markdown(mesh: str = "pod256") -> str:
    rows = load(mesh)
    lines = [
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
        "dominant | roofline frac | MODEL/HLO flops | fix |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']*1e3:.2f} | "
            f"{r['memory_s']*1e3:.2f} | {r['collective_s']*1e3:.2f} | "
            f"**{r['dominant']}** | {r['roofline_fraction']:.2f} | "
            f"{r['useful_ratio']:.2f} | {FIX_HINTS[r['dominant']][:60]} |")
    return "\n".join(lines)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--mesh", default="pod256")
    args = ap.parse_args()
    if args.markdown:
        print(markdown(args.mesh))
    else:
        sys.path.insert(0, os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
        print("name,us_per_call,derived")
        run()
