"""Training benchmark: guided optimizer-state offload under an HBM budget.

Runs the same smoke training twice — unconstrained vs a 60% HBM budget with
guided offload (``GuidanceRuntime`` over an ``ArenaBackend``) — and reports: loss parity (migration never changes
numerics), bytes migrated, and per-step transfer (rental) traffic.
``derived`` = final loss for loss rows; bytes for traffic rows."""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.core import GuidanceConfig
from repro.data import SyntheticLM
from repro.launch.analysis import guidance_summary
from repro.models import build_model
from repro.optim import AdamW
from repro.train import Trainer, TrainerConfig

from .common import emit


def run(quick: bool = False):
    steps = 10 if quick else 25
    cfg = dataclasses.replace(get_smoke("llama3_2_1b"), remat=False)
    model = build_model(cfg)
    opt = AdamW(lr=3e-3, weight_decay=0.0)
    src = SyntheticLM(cfg.vocab, 64, 4, seed=3)
    data = [{k: jnp.asarray(v) for k, v in src.batch_np(i).items()}
            for i in range(steps + 1)]

    rows = []
    t0 = time.perf_counter()
    tr = Trainer(model, opt, TrainerConfig(steps=steps, log_every=1),
                 rng=jax.random.PRNGKey(5))
    tr.run(iter(data))
    base_wall = time.perf_counter() - t0
    base_loss = tr.metrics_log[-1]["loss"]
    rows.append(("train/baseline/final_loss", base_wall * 1e6, base_loss))

    state_bytes = sum(a.size * a.dtype.itemsize
                      for a in jax.tree.leaves(tr.params))
    state_bytes += 2 * sum(a.size * a.dtype.itemsize
                           for a in jax.tree.leaves(tr.opt_state.m))
    gdt = GuidanceConfig(enabled=True, strategy="thermos",
                         fast_capacity_bytes=int(state_bytes * 0.6),
                         interval_steps=5, promotion_threshold=1024)
    t0 = time.perf_counter()
    tr2 = Trainer(model, opt, TrainerConfig(steps=steps, log_every=1,
                                            gdt=gdt),
                  rng=jax.random.PRNGKey(5))
    tr2.run(iter(data))
    gdt_wall = time.perf_counter() - t0
    gdt_loss = tr2.metrics_log[-1]["loss"]
    rows.append(("train/gdt_offload/final_loss", gdt_wall * 1e6, gdt_loss))
    rows.append(("train/gdt_offload/loss_delta", gdt_wall * 1e6,
                 abs(gdt_loss - base_loss)))
    guidance = guidance_summary(tr2.gdt.events)
    rows.append(("train/gdt_offload/bytes_migrated", gdt_wall * 1e6,
                 guidance["bytes_migrated"]))
    rows.append(("train/gdt_offload/migrations", gdt_wall * 1e6,
                 guidance["migrations"]))
    rows.append(("train/gdt_offload/rental_transfer_bytes", gdt_wall * 1e6,
                 tr2.placer.transfers_bytes))
    rows.append(("train/gdt_offload/slow_tier_bytes", gdt_wall * 1e6,
                 tr2.placer.slow_bytes()))
    return emit(rows)


if __name__ == "__main__":
    run()
