"""Fig. 8 reproduction: CORAL large/huge inputs against the full 192 GB DRAM
tier — guided software tiering vs hardware-managed caching (memory mode),
plus the beyond-paper fragmentation fix.  ``derived`` = throughput relative
to unguided first touch (the Fig. 8 y-axis)."""

from __future__ import annotations

from repro.core import CLX
from repro.mem import MemorySimulator
from repro.mem.workloads import amg, lulesh, qmcpack, snap

from .common import emit, timed

DRAM = CLX.fast.capacity_bytes


def run(quick: bool = False):
    rows = []
    cases = [(lulesh, "large"), (amg, "large"), (snap, "large"), (qmcpack, "large")]
    if not quick:
        cases += [(lulesh, "huge"), (amg, "huge"), (snap, "huge"), (qmcpack, "huge")]
    for wlf, size in cases:
        wl = wlf(size)
        sim = MemorySimulator(CLX, wl)
        ft = sim.run_first_touch(DRAM)
        for policy, runner in (
            ("offline", lambda: sim.run_offline(DRAM)),
            ("online", lambda: sim.run_online(DRAM)),
            ("hw_cache", lambda: sim.run_hw_cache(DRAM)),
            ("online_frag", lambda: sim.run_online(DRAM, fragmentation=True)),
        ):
            res, us = timed(runner)
            rows.append((f"fig8/{wl.name}/{policy}", us, res.speedup_over(ft)))
    return emit(rows)


if __name__ == "__main__":
    run()
