#!/usr/bin/env bash
# Run the repro.check static contract linter over the tree.
#
#   ./scripts/check.sh                          # text report
#   ./scripts/check.sh --format json            # machine-readable
#   ./scripts/check.sh src/repro/serve          # a subtree
#
# Exit code is the finding count (0 = clean), which is the CI gate.
# Arguments are passed straight through to `python -m repro.check`; when
# no path operand is given the full checked tree is used.
set -u
cd "$(dirname "$0")/.."

paths_given=0
expect_value=0
for arg in "$@"; do
    if [ "$expect_value" -eq 1 ]; then
        expect_value=0
        continue
    fi
    case "$arg" in
        --format|--rules|--output) expect_value=1 ;;
        --*) ;;
        *) paths_given=1 ;;
    esac
done

if [ "$paths_given" -eq 0 ]; then
    set -- src tests benchmarks examples "$@"
fi

mkdir -p results
PYTHONPATH=src exec python -m repro.check "$@"
