"""Quickstart: build an architecture from the registry, train a few steps on
synthetic data, then generate from it through the ``LLM`` front door — all
on CPU in under a minute.

    PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses

import jax.numpy as jnp

from repro.configs import ARCHS, get_smoke
from repro.data import SyntheticLM
from repro.models import build_model
from repro.optim import AdamW
from repro.serve import LLM, SamplingParams
from repro.train import Trainer, TrainerConfig


def main():
    print(f"registered architectures: {', '.join(ARCHS)}")
    cfg = dataclasses.replace(get_smoke("llama3_2_1b"), remat=False)
    model = build_model(cfg)

    # --- train a few steps -------------------------------------------------
    opt = AdamW(lr=1e-2, weight_decay=0.0)
    trainer = Trainer(model, opt,
                      TrainerConfig(steps=20, log_every=5, seed=0))
    src = SyntheticLM(cfg.vocab, seq_len=64, global_batch=8, seed=0)

    def batches():
        for b in src.iter_host():
            yield {k: jnp.asarray(v) for k, v in b.items()}

    result = trainer.run(batches())
    print(f"trained {result['steps']} steps, "
          f"final loss {result['final_loss']:.3f}")

    # --- generate ----------------------------------------------------------
    # The whole serving stack — paged KV cache, continuous batching, guided
    # tiering — sits invisibly behind three lines:
    llm = LLM(model, trainer.params)
    out = llm.generate([5, 42, 17], SamplingParams(max_tokens=8))[0]
    print("prompt:", out.prompt_token_ids, "->", out.token_ids,
          f"({out.finish_reason})")


if __name__ == "__main__":
    main()
