"""Quickstart: build an architecture from the registry, train a few steps on
synthetic data, then decode from it — all on CPU in under a minute.

    PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_smoke
from repro.data import SyntheticLM
from repro.models import build_model
from repro.optim import AdamW
from repro.train import Trainer, TrainerConfig


def main():
    print(f"registered architectures: {', '.join(ARCHS)}")
    cfg = dataclasses.replace(get_smoke("llama3_2_1b"), remat=False)
    model = build_model(cfg)

    # --- train a few steps -------------------------------------------------
    opt = AdamW(lr=1e-2, weight_decay=0.0)
    trainer = Trainer(model, opt, TrainerConfig(steps=20, log_every=5))
    src = SyntheticLM(cfg.vocab, seq_len=64, global_batch=8, seed=0)

    def batches():
        for b in src.iter_host():
            yield {k: jnp.asarray(v) for k, v in b.items()}

    result = trainer.run(batches())
    print(f"trained {result['steps']} steps, "
          f"final loss {result['final_loss']:.3f}")

    # --- decode ------------------------------------------------------------
    cache = model.init_cache(1, 64)
    tokens = [5, 42, 17]
    decode = jax.jit(model.decode)
    logits = None
    for t, tok in enumerate(tokens):
        logits, cache = decode(trainer.params, cache,
                               jnp.asarray([[tok]], jnp.int32), jnp.int32(t))
    out = []
    pos = len(tokens)
    for _ in range(8):
        nxt = int(jnp.argmax(logits[0]))
        out.append(nxt)
        logits, cache = decode(trainer.params, cache,
                               jnp.asarray([[nxt]], jnp.int32),
                               jnp.int32(pos))
        pos += 1
    print("prompt:", tokens, "->", out)


if __name__ == "__main__":
    main()
