"""End-to-end training driver (deliverable b): train a ~100M-parameter
decoder LM for a few hundred steps with the paper's online guidance managing
HBM-vs-host placement of the training state under a budget.

    PYTHONPATH=src python examples/train_guided_offload.py            # ~100M, 300 steps
    PYTHONPATH=src python examples/train_guided_offload.py --tiny     # CI-sized

The run prints: loss curve, the controller's migration decisions
(ski-rental rental vs purchase), what ended up on the host tier, and the
per-step rental (PCIe) traffic.
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.core import GuidanceConfig
from repro.data import SyntheticLM
from repro.models import build_model
from repro.models.common import count_params
from repro.models.config import ModelConfig
from repro.optim import AdamW, cosine_schedule
from repro.train import Trainer, TrainerConfig


def make_config(tiny: bool) -> ModelConfig:
    if tiny:
        return ModelConfig(arch="lm-12m", family="dense", n_layers=4,
                           d_model=128, n_heads=4, kv_heads=4, d_ff=512,
                           vocab=8192, remat=False)
    # ~101M params: 2*32000*512 embeddings + 12 layers of d=512/ff=2048.
    return ModelConfig(arch="lm-100m", family="dense", n_layers=12,
                       d_model=512, n_heads=8, kv_heads=8, d_ff=2048,
                       vocab=32000, remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--budget-frac", type=float, default=0.6,
                    help="HBM budget as a fraction of training state")
    args = ap.parse_args()

    cfg = make_config(args.tiny)
    steps = args.steps or (40 if args.tiny else 300)
    model = build_model(cfg)
    n = count_params(model.param_defs())
    print(f"model: {cfg.arch}  params={n/1e6:.1f}M  steps={steps}")

    state_bytes = int(n * 2 + 2 * n * 4)     # bf16 params + f32 m,v
    budget = int(state_bytes * args.budget_frac)
    print(f"training state ~{state_bytes/2**20:.0f} MiB, "
          f"HBM budget {budget/2**20:.0f} MiB "
          f"({args.budget_frac:.0%}) -> guidance must offload the rest")

    gdt = GuidanceConfig(enabled=True, strategy="thermos",
                    fast_capacity_bytes=budget, interval_steps=10,
                    promotion_threshold=256 * 1024)
    opt = AdamW(lr=cosine_schedule(3e-3, warmup=steps // 10, total=steps))
    trainer = Trainer(model, opt,
                      TrainerConfig(steps=steps,
                                    log_every=max(steps // 10, 1), gdt=gdt,
                                    seed=0))

    src = SyntheticLM(cfg.vocab, seq_len=256 if not args.tiny else 64,
                      global_batch=8, seed=0)

    def batches():
        for b in src.iter_host():
            yield {k: jnp.asarray(v) for k, v in b.items()}

    result = trainer.run(batches())
    print("\nloss curve:")
    for m in trainer.metrics_log:
        print(f"  step {int(m['step']):4d}  loss {m['loss']:.4f}")

    print("\ntiering outcome:")
    print(f"  migrations:            {result['migrations']}")
    print(f"  bytes migrated:        {result['bytes_migrated']/2**20:.1f} MiB")
    print(f"  rental transfers:      {result['transfer_bytes']/2**20:.1f} MiB")
    print(f"  resident on host tier: {trainer.placer.slow_bytes()/2**20:.1f} MiB")
    print(f"  resident in HBM:       {trainer.placer.fast_bytes()/2**20:.1f} MiB")
    for rec in trainer.gdt.history:
        if rec.migrated:
            d = rec.decision
            print(f"  interval {rec.interval_index}: migrated "
                  f"{rec.bytes_moved/2**20:.1f} MiB "
                  f"(rental {d.rental_cost_ns/1e6:.1f} ms > purchase "
                  f"{d.purchase_cost_ns/1e6:.1f} ms)")
    # Groups on the slow tier, by site label:
    slow = [
        (key, sum(e.nbytes for e in trainer.placer.entries(arena.arena_id)
                  if e.array.sharding.memory_kind == "pinned_host"))
        for key, (site, arena, names) in trainer._site_groups.items()
    ]
    slow = [(k, b) for k, b in slow if b]
    if slow:
        print("\nhost-tier site groups (coldest first):")
        for k, b in sorted(slow, key=lambda kb: -kb[1])[:10]:
            print(f"  {k:40s} {b/2**20:8.1f} MiB")


if __name__ == "__main__":
    main()
