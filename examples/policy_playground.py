"""Policy playground: the paper's decision machinery on a synthetic
workload, no JAX involved — watch knapsack / hotset / thermos disagree and
the ski-rental break-even rule decide when migration pays.

    PYTHONPATH=src python examples/policy_playground.py
"""

from repro.core import (
    ArenaBackend,
    ArenaManager,
    CLX,
    GuidanceConfig,
    GuidanceRuntime,
    SiteKind,
    SiteRegistry,
    recommend,
)

MB = 2**20


def main():
    reg = SiteRegistry()
    mgr = ArenaManager(reg, promotion_threshold=1 * MB,
                       fast_capacity_bytes=100 * MB)
    # A workload: hot small site, warm big site, cold big site; the big
    # ones arrive first (first-touch grabs the fast tier).
    cold = reg.register(["big_cold_array"], SiteKind.OTHER)
    warm = reg.register(["big_warm_array"], SiteKind.OTHER)
    hot = reg.register(["hot_workset"], SiteKind.OTHER)
    mgr.allocate(cold, 60 * MB)
    mgr.allocate(warm, 50 * MB)
    a_hot = mgr.allocate(hot, 30 * MB)
    print("first-touch placement (fast fraction):")
    for a in mgr:
        print(f"  {a.site.label:16s} {a.resident_bytes/MB:5.0f} MiB  "
              f"fast={a.fast_fraction:.2f}")

    backend = ArenaBackend(mgr, CLX)
    gdt = GuidanceRuntime(backend, CLX,
                          GuidanceConfig(strategy="thermos",
                                         fast_capacity_bytes=100 * MB,
                                         interval_steps=1))
    print("\nintervals (10k accesses/interval to hot, 3k to warm, 10 cold):")
    for i in range(8):
        mgr.touch(hot, 200_000)
        mgr.touch(warm, 60_000)
        mgr.touch(cold, 10)
        rec = gdt.on_step()
        d = rec.decision
        print(f"  t={i}: rental {d.rental_cost_ns/1e6:8.2f} ms vs purchase "
              f"{d.purchase_cost_ns/1e6:8.2f} ms -> "
              f"{'MIGRATE' if rec.migrated else 'wait'}"
              + (f" ({rec.bytes_moved/MB:.0f} MiB)" if rec.migrated else ""))
    print("\nfinal placement:")
    for a in mgr:
        print(f"  {a.site.label:16s} fast={a.fast_fraction:.2f}")

    # Compare the three MemBrain engines on the same profile.
    prof = backend.profiler.snapshot()
    print("\nrecommendation engines at 100 MiB capacity:")
    for strat in ("knapsack", "hotset", "thermos"):
        recs = recommend(prof, 100 * MB, strat)
        desc = ", ".join(
            f"{r.label}={recs.fractions.get(r.arena_id, 0.0):.2f}"
            for r in prof.rows)
        print(f"  {strat:8s}: {desc}")


if __name__ == "__main__":
    main()
