"""Serving with guided KV-page tiering: multi-turn sessions plus one-shot
"scan" requests compete for a small HBM page pool; the paper's machinery
(thermos + age fragmentation + ski-rental + decay) places pages across
HBM/host and is compared against LRU and FIFO eviction.

Everything goes through the ``LLM`` front door — sessions are submitted
handles, pause/resume are session controls, and the tier machinery stays
invisible behind ``generate``/``submit``.

    PYTHONPATH=src python examples/serve_guided_kv.py
"""

import dataclasses

import jax
import numpy as np

from repro.configs import get_smoke
from repro.models import build_model
from repro.serve import LLM, SamplingParams, ServeConfig


def run_policy(model, params, policy: str):
    llm = LLM(model, params, ServeConfig(
        max_batch=2, page_size=4, hbm_pages=12, host_pages=160,
        policy=policy, interval_steps=4))
    rng = np.random.default_rng(0)
    prompt = [2, 7, 1, 8, 2, 8]
    for rid in range(4):
        llm.submit(prompt, SamplingParams(max_tokens=64), request_id=rid)
        llm.pause(rid)
    hot, scan_id = [0, 1], 1000
    for r in range(10):
        for rid in hot:
            if llm.is_live(rid):
                llm.resume(rid)
        extra = 2 + (r // 5) % 2
        if r % 5 == 4 and llm.is_live(extra):
            llm.resume(extra)
        llm.step(); llm.step()
        if r % 2 == 1:   # one-shot scan session (cache pollution attempt)
            llm.submit([int(t) for t in rng.integers(1, 400, 16)],
                       SamplingParams(max_tokens=2), request_id=scan_id)
            llm.step(); llm.step()
            scan_id += 1
        for rid in list(llm.engine.requests):
            if llm.engine.requests[rid].state == "active":
                llm.pause(rid)
    return llm.stats()


def main():
    cfg = dataclasses.replace(get_smoke("llama3_2_1b"), remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"{'policy':8s} {'swap-ins':>8s} {'swap-outs':>9s} "
          f"{'bytes moved':>12s}")
    base = None
    for policy in ("gdt", "lru", "fifo"):
        s = run_policy(model, params, policy)
        if policy == "gdt":
            base = s["bytes_moved"]
        rel = f"({s['bytes_moved']/max(base,1):.2f}x gdt)"
        print(f"{policy:8s} {s['swap_ins']:8d} {s['swap_outs']:9d} "
              f"{s['bytes_moved']/1024:9.0f} KiB {rel}")
    print("\ngdt resists scan pollution: one-shot pages never build access "
          "density, so thermos leaves them on the host tier while hot "
          "sessions keep their pages resident.")


if __name__ == "__main__":
    main()
